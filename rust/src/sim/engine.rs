//! Discrete-event simulation of a microservice pipeline deployed on a
//! cluster of spatial-multitasking GPUs.
//!
//! Models exactly the phenomena the paper measures: per-instance
//! dynamic batching, SM-quota execution (Amdahl + roofline via
//! [`CostModel`]), global-memory-bandwidth contention between co-located
//! kernels, PCIe contention on uploads/hops/downloads, and the choice of
//! communication mechanism per hop (§VI). The engine is the measurement
//! substrate for every figure harness and for the coordinator's ramp
//! searches.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::comm::{hop_cost, CommMode};
use crate::config::ClusterSpec;
use crate::metrics::LatencyHistogram;
use crate::suite::workload::PoissonArrivals;
use crate::suite::Pipeline;

use super::cost::CostModel;
use super::gpu::SimGpu;
use super::pcie::PcieBus;

/// One microservice instance pinned to a GPU with an SM quota.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstancePlacement {
    pub stage: usize,
    pub gpu: usize,
    pub sm_frac: f64,
}

/// A full deployment of one pipeline.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub placements: Vec<InstancePlacement>,
    /// Query batch size (the x-axis of Figs 14/19).
    pub batch: u32,
    /// Mechanism used for same-GPU hops.
    pub comm: CommMode,
}

impl Deployment {
    /// Instances per stage (N_i in Table II).
    pub fn instances_per_stage(&self, n_stages: usize) -> Vec<usize> {
        let mut n = vec![0; n_stages];
        for p in &self.placements {
            n[p.stage] += 1;
        }
        n
    }

    /// Σ SM quota across all instances (the resource-usage metric of
    /// Figs 16/17/21, in GPU-equivalents).
    pub fn total_sm_usage(&self) -> f64 {
        self.placements.iter().map(|p| p.sm_frac).sum()
    }

    /// Number of distinct GPUs used.
    pub fn gpus_used(&self) -> usize {
        let mut gpus: Vec<usize> = self.placements.iter().map(|p| p.gpu).collect();
        gpus.sort_unstable();
        gpus.dedup();
        gpus.len()
    }
}

/// Simulation options.
///
/// The arrival unit is a *request* of `deployment.batch` queries — the
/// paper's workload protocol (the Fig 14/19 x-axis is "the batch size of
/// processing user queries": clients submit batched queries, and the
/// coordinator's own dynamic batcher — exercised by the real
/// `coordinator::Batcher` — is already full at the loads the peak search
/// measures).
#[derive(Debug, Clone)]
pub struct SimOptions {
    pub seed: u64,
    /// Total user queries injected (requests = queries / batch).
    pub queries: usize,
    /// Fraction of earliest completions excluded from the histogram.
    pub warmup_frac: f64,
    /// Retained for the coordinator-side batcher; the request-granular
    /// engine issues immediately.
    pub max_wait_frac: f64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { seed: 42, queries: 6_000, warmup_frac: 0.1, max_wait_frac: 0.15 }
    }
}

/// Where the wall-clock time of completed queries went (Fig 5).
#[derive(Debug, Clone, Copy, Default)]
pub struct TimeBreakdown {
    pub queue_s: f64,
    pub exec_s: f64,
    /// host→device input upload (stage-1 ingress)
    pub upload_s: f64,
    /// inter-stage hops
    pub hop_s: f64,
    /// device→host result download (egress)
    pub download_s: f64,
}

impl TimeBreakdown {
    pub fn comm_total(&self) -> f64 {
        self.upload_s + self.hop_s + self.download_s
    }

    pub fn total(&self) -> f64 {
        self.queue_s + self.exec_s + self.comm_total()
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub hist: LatencyHistogram,
    pub offered_qps: f64,
    pub achieved_qps: f64,
    pub completed: u64,
    pub breakdown: TimeBreakdown,
    /// Mean exec time per stage (co-located, i.e. contended) — Fig 4b.
    pub stage_exec_mean_s: Vec<f64>,
}

impl SimReport {
    pub fn p99(&self) -> f64 {
        self.hist.p99()
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Ev {
    Arrival { qid: u32 },
    ExecDone { inst: usize },
    /// Release one PCIe stream registered at transfer start.
    BusRelease,
    /// Deliver queries to `target` (None = final completion).
    XferDone { target: Option<usize>, qids: Vec<u32> },
}

#[derive(Debug)]
struct Event {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: reverse on time, then sequence for determinism
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

struct Instance {
    stage: usize,
    gpu: usize,
    sm_frac: f64,
    queue: VecDeque<(u32, f64)>, // (qid, ready time)
    busy: bool,
    /// qids of the batch currently executing (while busy)
    exec: Option<Vec<u32>>,
}

/// The engine itself. Build with [`Simulator::new`], run with
/// [`Simulator::run`].
pub struct Simulator<'a> {
    pipeline: &'a Pipeline,
    cluster: &'a ClusterSpec,
    deployment: &'a Deployment,
    opts: SimOptions,
}

impl<'a> Simulator<'a> {
    pub fn new(
        pipeline: &'a Pipeline,
        cluster: &'a ClusterSpec,
        deployment: &'a Deployment,
        opts: SimOptions,
    ) -> Self {
        Simulator { pipeline, cluster, deployment, opts }
    }

    /// Statically validate the deployment (capacity, contexts, memory —
    /// Constraints 1/2/4 of Eq. 1). Returns the admitted GPU states.
    pub fn admit(&self) -> Result<Vec<SimGpu>, String> {
        let mut gpus: Vec<SimGpu> = (0..self.cluster.num_gpus)
            .map(|_| SimGpu::new(self.cluster.gpu.clone()))
            .collect();
        let n_stages = self.pipeline.n_stages();
        for p in &self.deployment.placements {
            if p.stage >= n_stages {
                return Err(format!("placement references stage {}", p.stage));
            }
            if p.gpu >= gpus.len() {
                return Err(format!("placement references gpu {}", p.gpu));
            }
            let st = &self.pipeline.stages[p.stage];
            gpus[p.gpu]
                .admit(
                    &st.name,
                    p.sm_frac,
                    st.model_bytes,
                    st.act_bytes_per_query * self.deployment.batch as f64,
                )
                .map_err(|e| format!("gpu {} rejects {}: {e}", p.gpu, st.name))?;
        }
        for i in 0..n_stages {
            if !self.deployment.placements.iter().any(|p| p.stage == i) {
                return Err(format!("stage {i} has no instances"));
            }
        }
        Ok(gpus)
    }

    /// Run the simulation at the given offered load.
    pub fn run(&self, offered_qps: f64) -> Result<SimReport, String> {
        let mut gpus = self.admit()?;
        let cost = CostModel::new(self.cluster.gpu.clone());
        let mut bus = PcieBus::new(self.cluster.pcie.clone());
        let ipc = &self.cluster.ipc;
        let batch = self.deployment.batch.max(1) as usize;
        // arrival unit: one request = `batch` queries
        let n_requests = (self.opts.queries + batch - 1) / batch;
        let req_rate = offered_qps / batch as f64;

        let mut instances: Vec<Instance> = self
            .deployment
            .placements
            .iter()
            .map(|p| Instance {
                stage: p.stage,
                gpu: p.gpu,
                sm_frac: p.sm_frac,
                queue: VecDeque::new(),
                busy: false,
                exec: None,
            })
            .collect();
        let mut by_stage: Vec<Vec<usize>> = vec![Vec::new(); self.pipeline.n_stages()];
        for (i, inst) in instances.iter().enumerate() {
            by_stage[inst.stage].push(i);
        }

        // generate all request arrivals up front
        let mut arrivals: Vec<f64>;
        {
            let mut horizon = n_requests as f64 / req_rate * 1.25 + 1.0;
            loop {
                arrivals = PoissonArrivals::new(req_rate, self.opts.seed).times_until(horizon);
                if arrivals.len() >= n_requests {
                    arrivals.truncate(n_requests);
                    break;
                }
                horizon *= 1.5;
            }
        }

        let mut heap = BinaryHeap::with_capacity(n_requests * 6);
        let mut seq = 0u64;
        let push = |heap: &mut BinaryHeap<Event>, seq: &mut u64, t: f64, ev: Ev| {
            *seq += 1;
            heap.push(Event { t, seq: *seq, ev });
        };
        for (qid, &t) in arrivals.iter().enumerate() {
            push(&mut heap, &mut seq, t, Ev::Arrival { qid: qid as u32 });
        }

        let mut hist = LatencyHistogram::new();
        let mut breakdown = TimeBreakdown::default();
        let mut stage_exec_sum = vec![0.0f64; self.pipeline.n_stages()];
        let mut stage_exec_n = vec![0u64; self.pipeline.n_stages()];
        let warmup = (n_requests as f64 * self.opts.warmup_frac) as u64;
        let mut completed = 0u64;
        let mut first_counted_t = f64::NAN;
        let mut last_t = 0.0f64;

        // borrow-friendly helper: join-shortest-queue routing counting
        // the in-flight request, preferring same-GPU targets (IPC
        // locality) and breaking remaining ties round-robin so idle
        // instances share work (the paper's scheduler routes across
        // instances).
        fn route(
            by_stage: &[Vec<usize>],
            instances: &[Instance],
            stage: usize,
            from_gpu: Option<usize>,
            rr: &mut usize,
        ) -> usize {
            let cands = &by_stage[stage];
            *rr = rr.wrapping_add(1);
            let start = *rr % cands.len();
            let mut best = cands[start];
            let mut best_key = (usize::MAX, true);
            for k in 0..cands.len() {
                let i = cands[(start + k) % cands.len()];
                let load = instances[i].queue.len() + instances[i].busy as usize;
                let cross = from_gpu.map_or(false, |g| instances[i].gpu != g);
                let key = (load, cross);
                if key < best_key {
                    best_key = key;
                    best = i;
                }
            }
            best
        }
        let mut rr_counters = vec![0usize; self.pipeline.n_stages()];

        // issue a batch on `inst` if warranted; schedules events.
        #[allow(clippy::too_many_arguments)]
        fn try_issue(
            inst_id: usize,
            now: f64,
            instances: &mut [Instance],
            gpus: &mut [SimGpu],
            bus: &mut PcieBus,
            cost: &CostModel,
            pipeline: &Pipeline,
            batch: usize,
            heap: &mut BinaryHeap<Event>,
            seq: &mut u64,
            breakdown: &mut TimeBreakdown,
            stage_exec_sum: &mut [f64],
            stage_exec_n: &mut [u64],
        ) {
            let push = |heap: &mut BinaryHeap<Event>, seq: &mut u64, t: f64, ev: Ev| {
                *seq += 1;
                heap.push(Event { t, seq: *seq, ev });
            };
            let inst = &mut instances[inst_id];
            if inst.busy || inst.queue.is_empty() {
                return;
            }
            // one request (= `batch` queries) per execution
            let (rid, ready) = inst.queue.pop_front().unwrap();
            let qids = vec![rid];
            let n = batch;
            breakdown.queue_s += (now - ready) * n as f64;
            inst.busy = true;

            let stage = &pipeline.stages[inst.stage];
            let gpu = inst.gpu;
            let sm = inst.sm_frac;
            let stage_idx = inst.stage;

            // stage-0 ingress crosses PCIe before the kernel runs
            let mut start = now;
            if stage_idx == 0 {
                let bytes = stage.in_bytes_per_query * n as f64;
                let up = bus.begin_transfer(bytes);
                push(heap, seq, now + up, Ev::BusRelease);
                breakdown.upload_s += up * n as f64;
                start += up;
            }
            let others = gpus[gpu].kernel_start(
                inst_id,
                cost.bw_demand(stage, n as u32, sm),
            );
            let dur = cost.duration_contended(stage, n as u32, sm, others);
            stage_exec_sum[stage_idx] += dur;
            stage_exec_n[stage_idx] += 1;
            breakdown.exec_s += dur * n as f64;
            push(heap, seq, start + dur, Ev::ExecDone { inst: inst_id });
            instances[inst_id].exec = Some(qids);
        }

        while let Some(Event { t: now, ev, .. }) = heap.pop() {
            last_t = now;
            match ev {
                Ev::Arrival { qid } => {
                    let target = route(&by_stage, &instances, 0, None, &mut rr_counters[0]);
                    instances[target].queue.push_back((qid, now));
                    try_issue(
                        target, now, &mut instances, &mut gpus, &mut bus, &cost,
                        self.pipeline, batch, &mut heap,
                        &mut seq, &mut breakdown, &mut stage_exec_sum, &mut stage_exec_n,
                    );
                }
                Ev::BusRelease => bus.end_transfer(),
                Ev::ExecDone { inst: inst_id } => {
                    let qids = instances[inst_id].exec.take().unwrap_or_default();
                    let stage_idx = instances[inst_id].stage;
                    let gpu = instances[inst_id].gpu;
                    gpus[gpu].kernel_end(inst_id);
                    instances[inst_id].busy = false;
                    let n = (qids.len() * batch) as f64;
                    let is_last = stage_idx + 1 == self.pipeline.n_stages();
                    if is_last {
                        // egress download crosses PCIe
                        let bytes =
                            self.pipeline.stages[stage_idx].out_bytes_per_query * n;
                        let dl = bus.begin_transfer(bytes);
                        push(&mut heap, &mut seq, now + dl, Ev::BusRelease);
                        breakdown.download_s += dl * n;
                        push(&mut heap, &mut seq, now + dl, Ev::XferDone { target: None, qids });
                    } else {
                        let target = route(
                            &by_stage, &instances, stage_idx + 1, Some(gpu),
                            &mut rr_counters[stage_idx + 1],
                        );
                        let same_gpu = instances[target].gpu == gpu;
                        let bytes =
                            self.pipeline.stages[stage_idx].out_bytes_per_query * n;
                        let hop = hop_cost(self.deployment.comm, same_gpu, bytes, &mut bus, ipc);
                        if hop.uses_bus {
                            push(&mut heap, &mut seq, now + hop.duration_s, Ev::BusRelease);
                        }
                        breakdown.hop_s += hop.duration_s * n;
                        push(
                            &mut heap, &mut seq, now + hop.duration_s,
                            Ev::XferDone { target: Some(target), qids },
                        );
                    }
                    // instance freed: maybe issue the next batch
                    try_issue(
                        inst_id, now, &mut instances, &mut gpus, &mut bus, &cost,
                        self.pipeline, batch, &mut heap,
                        &mut seq, &mut breakdown, &mut stage_exec_sum, &mut stage_exec_n,
                    );
                }
                Ev::XferDone { target, qids } => match target {
                    Some(t_inst) => {
                        for qid in qids {
                            instances[t_inst].queue.push_back((qid, now));
                        }
                        try_issue(
                            t_inst, now, &mut instances, &mut gpus, &mut bus, &cost,
                            self.pipeline, batch, &mut heap,
                            &mut seq, &mut breakdown, &mut stage_exec_sum, &mut stage_exec_n,
                        );
                    }
                    None => {
                        for rid in qids {
                            completed += 1;
                            if completed > warmup {
                                if first_counted_t.is_nan() {
                                    first_counted_t = now;
                                }
                                hist.record(now - arrivals[rid as usize]);
                            }
                        }
                    }
                },
            }
        }

        let span = (last_t - first_counted_t).max(1e-9);
        let counted = completed.saturating_sub(warmup);
        Ok(SimReport {
            achieved_qps: counted as f64 * batch as f64 / span,
            offered_qps,
            completed,
            hist,
            breakdown,
            stage_exec_mean_s: stage_exec_sum
                .iter()
                .zip(&stage_exec_n)
                .map(|(s, &n)| if n == 0 { 0.0 } else { s / n as f64 })
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::suite::real;

    fn simple_deployment(comm: CommMode) -> Deployment {
        Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
                InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.5 },
            ],
            batch: 16,
            comm,
        }
    }

    #[test]
    fn all_queries_complete_at_low_load() {
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::GlobalIpc);
        let sim = Simulator::new(&p, &c, &d, SimOptions { queries: 1_000, ..Default::default() });
        let r = sim.run(50.0).unwrap();
        // completion unit is the request (= batch of 16 queries)
        assert_eq!(r.completed, 1_000 / 16 + 1);
        assert!(r.p99() > 0.0);
        assert!(r.p99() < 10.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = real::text_to_text();
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::MainMemory);
        let o = SimOptions { queries: 500, ..Default::default() };
        let a = Simulator::new(&p, &c, &d, o.clone()).run(40.0).unwrap();
        let b = Simulator::new(&p, &c, &d, o).run(40.0).unwrap();
        assert_eq!(a.p99(), b.p99());
        assert_eq!(a.completed, b.completed);
    }

    #[test]
    fn latency_grows_with_load() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::GlobalIpc);
        let o = SimOptions { queries: 2_000, ..Default::default() };
        let lo = Simulator::new(&p, &c, &d, o.clone()).run(20.0).unwrap();
        let hi = Simulator::new(&p, &c, &d, o).run(2_000.0).unwrap();
        assert!(
            hi.p99() > lo.p99(),
            "overload p99 {} must exceed light-load p99 {}",
            hi.p99(),
            lo.p99()
        );
    }

    #[test]
    fn ipc_beats_main_memory_on_image_pipeline() {
        // Fig 5/11: heavy payloads + same GPU ⇒ IPC reduces latency.
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let o = SimOptions { queries: 2_000, ..Default::default() };
        let mm = Simulator::new(&p, &c, &simple_deployment(CommMode::MainMemory), o.clone())
            .run(60.0)
            .unwrap();
        let gi = Simulator::new(&p, &c, &simple_deployment(CommMode::GlobalIpc), o)
            .run(60.0)
            .unwrap();
        assert!(
            gi.hist.mean() < mm.hist.mean(),
            "ipc mean {} vs mm mean {}",
            gi.hist.mean(),
            mm.hist.mean()
        );
        assert!(gi.breakdown.hop_s < mm.breakdown.hop_s);
    }

    #[test]
    fn admit_rejects_oversubscription() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let d = Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.8 },
                InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.5 },
            ],
            batch: 8,
            comm: CommMode::GlobalIpc,
        };
        assert!(Simulator::new(&p, &c, &d, SimOptions::default()).admit().is_err());
    }

    #[test]
    fn admit_rejects_missing_stage() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let d = Deployment {
            placements: vec![InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 }],
            batch: 8,
            comm: CommMode::GlobalIpc,
        };
        assert!(Simulator::new(&p, &c, &d, SimOptions::default()).admit().is_err());
    }

    #[test]
    fn breakdown_accounts_communication() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::MainMemory);
        let r = Simulator::new(&p, &c, &d, SimOptions { queries: 1_000, ..Default::default() })
            .run(40.0)
            .unwrap();
        let b = &r.breakdown;
        assert!(b.upload_s > 0.0 && b.hop_s > 0.0 && b.download_s > 0.0);
        // Fig 5 decomposes processing vs data transfer (queueing aside):
        // with main-memory comm the transfer share is large.
        let frac = b.comm_total() / (b.comm_total() + b.exec_s);
        assert!(frac > 0.15, "comm fraction {frac}");
    }
}
