//! Discrete-event simulation of a microservice pipeline deployed on a
//! cluster of spatial-multitasking GPUs.
//!
//! Models exactly the phenomena the paper measures: per-instance
//! dynamic batching, SM-quota execution (Amdahl + roofline via
//! [`CostModel`]), global-memory-bandwidth contention between co-located
//! kernels, PCIe contention on uploads/hops/downloads, and the choice of
//! communication mechanism per hop (§VI). The engine is the measurement
//! substrate for every figure harness and for the coordinator's ramp
//! searches.
//!
//! Two implementations share the same semantics:
//!
//! * [`Simulator::run`] — the optimized hot path: per-instance cost
//!   quantities are frozen once ([`cost::InstanceCost`]), events carry
//!   `u32` request handles instead of heap-allocated `Vec<u32>`
//!   payloads, Poisson arrivals stream lazily (no horizon guessing),
//!   and per-GPU contention is a sorted vector summed in instance-id
//!   order.
//! * [`Simulator::run_reference`] — the seed algorithm, kept as the
//!   golden reference: per-event [`CostModel`] calls, materialized
//!   arrival vector, vector-payload events. `tests/golden_engine.rs`
//!   asserts both produce identical results for fixed seeds.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use crate::comm::{hop_cost, CommMode};
use crate::config::ClusterSpec;
use crate::metrics::LatencyHistogram;
use crate::suite::workload::PoissonArrivals;
use crate::suite::Pipeline;

use super::cost::{CostModel, InstanceCost};
use super::gpu::SimGpu;
use super::pcie::PcieBus;

/// One microservice instance pinned to a GPU with an SM quota.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstancePlacement {
    pub stage: usize,
    pub gpu: usize,
    pub sm_frac: f64,
}

/// A full deployment of one pipeline.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub placements: Vec<InstancePlacement>,
    /// Query batch size (the x-axis of Figs 14/19).
    pub batch: u32,
    /// Mechanism used for same-GPU hops.
    pub comm: CommMode,
}

impl Deployment {
    /// Instances per stage (N_i in Table II).
    pub fn instances_per_stage(&self, n_stages: usize) -> Vec<usize> {
        let mut n = vec![0; n_stages];
        for p in &self.placements {
            n[p.stage] += 1;
        }
        n
    }

    /// Σ SM quota across all instances (the resource-usage metric of
    /// Figs 16/17/21, in GPU-equivalents).
    pub fn total_sm_usage(&self) -> f64 {
        self.placements.iter().map(|p| p.sm_frac).sum()
    }

    /// Number of distinct GPUs used.
    pub fn gpus_used(&self) -> usize {
        let mut gpus: Vec<usize> = self.placements.iter().map(|p| p.gpu).collect();
        gpus.sort_unstable();
        gpus.dedup();
        gpus.len()
    }
}

/// Simulation options.
///
/// The arrival unit is a *request* of `deployment.batch` queries — the
/// paper's workload protocol (the Fig 14/19 x-axis is "the batch size of
/// processing user queries": clients submit batched queries, and the
/// coordinator's own dynamic batcher — exercised by the real
/// `coordinator::Batcher` — is already full at the loads the peak search
/// measures). Batching *timeouts* therefore live in the coordinator's
/// `Batcher`, not here: the request-granular engine issues each request
/// as soon as its instance frees up.
#[derive(Debug, Clone)]
pub struct SimOptions {
    pub seed: u64,
    /// Total user queries injected (requests = queries / batch).
    pub queries: usize,
    /// Fraction of earliest completions excluded from the histogram.
    pub warmup_frac: f64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { seed: 42, queries: 6_000, warmup_frac: 0.1 }
    }
}

/// Where the wall-clock time of completed queries went (Fig 5).
#[derive(Debug, Clone, Copy, Default)]
pub struct TimeBreakdown {
    pub queue_s: f64,
    pub exec_s: f64,
    /// host→device input upload (stage-1 ingress)
    pub upload_s: f64,
    /// inter-stage hops
    pub hop_s: f64,
    /// device→host result download (egress)
    pub download_s: f64,
}

impl TimeBreakdown {
    pub fn comm_total(&self) -> f64 {
        self.upload_s + self.hop_s + self.download_s
    }

    pub fn total(&self) -> f64 {
        self.queue_s + self.exec_s + self.comm_total()
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub hist: LatencyHistogram,
    pub offered_qps: f64,
    pub achieved_qps: f64,
    pub completed: u64,
    pub breakdown: TimeBreakdown,
    /// Mean exec time per stage (co-located, i.e. contended) — Fig 4b.
    pub stage_exec_mean_s: Vec<f64>,
    /// Per-GPU peak *dynamic* KV-cache residency observed during the
    /// run, in bytes (`stage.mem_bytes_per_query × batch` held from
    /// kernel issue to completion). All zeros for KV-free pipelines.
    pub kv_peak_bytes: Vec<f64>,
}

impl SimReport {
    pub fn p99(&self) -> f64 {
        self.hist.p99()
    }
}

/// Time-and-sequence-ordered heap entry (min-heap on time, then on
/// insertion sequence for deterministic tie-breaking). Shared with the
/// multi-tenant engine in [`super::cluster`].
#[derive(Debug)]
pub(crate) struct Event<E> {
    pub(crate) t: f64,
    pub(crate) seq: u64,
    pub(crate) ev: E,
}

impl<E> PartialEq for Event<E> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<E> Eq for Event<E> {}
impl<E> PartialOrd for Event<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Event<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: reverse on time, then sequence for determinism
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Join-shortest-queue routing counting the in-flight request,
/// preferring same-GPU targets (IPC locality) and breaking remaining
/// ties round-robin so idle instances share work (the paper's scheduler
/// routes across instances). Shared by both engine implementations and
/// the multi-tenant [`super::cluster`] engine so their trajectories are
/// identical.
pub(crate) fn route_by<Fl, Fg>(
    cands: &[usize],
    from_gpu: Option<usize>,
    rr: &mut usize,
    load: Fl,
    gpu_of: Fg,
) -> usize
where
    Fl: Fn(usize) -> usize,
    Fg: Fn(usize) -> usize,
{
    *rr = rr.wrapping_add(1);
    let start = *rr % cands.len();
    let mut best = cands[start];
    let mut best_key = (usize::MAX, true);
    for k in 0..cands.len() {
        let i = cands[(start + k) % cands.len()];
        let cross = from_gpu.map_or(false, |g| gpu_of(i) != g);
        let key = (load(i), cross);
        if key < best_key {
            best_key = key;
            best = i;
        }
    }
    best
}

/// Validate one deployment's placements and admit them into `gpus`
/// (stage/GPU bounds, per-GPU SM/context/memory ledgers, stage
/// coverage). Shared by [`Simulator::admit`] and the multi-tenant
/// merged admission in [`super::cluster::ClusterSim`], so a new
/// admission rule automatically applies to both.
pub(crate) fn admit_deployment(
    pipeline: &Pipeline,
    deployment: &Deployment,
    gpus: &mut [SimGpu],
) -> Result<(), String> {
    let n_stages = pipeline.n_stages();
    for p in &deployment.placements {
        if p.stage >= n_stages {
            return Err(format!("placement references stage {}", p.stage));
        }
        if p.gpu >= gpus.len() {
            return Err(format!("placement references gpu {}", p.gpu));
        }
        let st = &pipeline.stages[p.stage];
        gpus[p.gpu]
            .admit(
                &st.name,
                p.sm_frac,
                st.model_bytes,
                st.act_bytes_per_query * deployment.batch as f64,
            )
            .map_err(|e| format!("gpu {} rejects {}: {e}", p.gpu, st.name))?;
    }
    for i in 0..n_stages {
        if !deployment.placements.iter().any(|p| p.stage == i) {
            return Err(format!("stage {i} has no instances"));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Optimized engine
// ---------------------------------------------------------------------

/// Optimized event payloads: request ids are plain `u32` handles into
/// the arrival-time arena — no per-event heap allocation.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// Request `rid` enters the system (schedules the next arrival).
    Arrival { rid: u32 },
    ExecDone { inst: usize },
    /// Release one PCIe stream registered at transfer start.
    BusRelease,
    /// Deliver request `rid` to instance `target`.
    Deliver { target: usize, rid: u32 },
    /// Request `rid` leaves the system.
    Complete { rid: u32 },
}

/// Per-instance runtime state with the frozen cost quantities inline.
struct Inst {
    stage: usize,
    gpu: usize,
    queue: VecDeque<(u32, f64)>, // (rid, ready time)
    busy: bool,
    /// rid of the request currently executing (valid while busy)
    exec_rid: u32,
    cost: InstanceCost,
    /// `in_bytes_per_query * batch`, frozen (stage-0 ingress payload).
    in_bytes_batch: f64,
    /// `out_bytes_per_query * batch`, frozen (hop/egress payload).
    out_bytes_batch: f64,
    /// `mem_bytes_per_query * batch`, frozen — dynamic KV-cache bytes
    /// held on the GPU while a request executes (0 ⇒ no KV gating).
    kv_bytes_batch: f64,
}

/// Per-GPU ledger of running kernels' bandwidth demands, kept sorted by
/// instance id so the Σ-demand reduction accumulates in the same order
/// as the reference engine's BTreeMap (bit-identical f64 sums). With
/// multiple tenants the ids are cluster-global, so cross-pipeline
/// contention sums stay instance-id-ordered too.
#[derive(Default)]
pub(crate) struct GpuLedger {
    running: Vec<(usize, f64)>,
}

impl GpuLedger {
    /// Register a starting kernel; returns Σ demand of the others.
    #[inline]
    pub(crate) fn kernel_start(&mut self, inst: usize, demand: f64) -> f64 {
        let mut others = 0.0;
        for &(_, d) in &self.running {
            others += d;
        }
        let pos = self.running.partition_point(|&(i, _)| i < inst);
        self.running.insert(pos, (inst, demand));
        others
    }

    #[inline]
    pub(crate) fn kernel_end(&mut self, inst: usize) {
        if let Some(pos) = self.running.iter().position(|&(i, _)| i == inst) {
            self.running.remove(pos);
        }
    }
}

/// The engine itself. Build with [`Simulator::new`], run with
/// [`Simulator::run`].
pub struct Simulator<'a> {
    pipeline: &'a Pipeline,
    cluster: &'a ClusterSpec,
    deployment: &'a Deployment,
    opts: SimOptions,
}

impl<'a> Simulator<'a> {
    pub fn new(
        pipeline: &'a Pipeline,
        cluster: &'a ClusterSpec,
        deployment: &'a Deployment,
        opts: SimOptions,
    ) -> Self {
        Simulator { pipeline, cluster, deployment, opts }
    }

    /// Statically validate the deployment (capacity, contexts, memory —
    /// Constraints 1/2/4 of Eq. 1). Returns the admitted GPU states —
    /// each built from its own per-GPU spec on a heterogeneous pool.
    pub fn admit(&self) -> Result<Vec<SimGpu>, String> {
        let mut gpus: Vec<SimGpu> = (0..self.cluster.num_gpus)
            .map(|g| SimGpu::new(self.cluster.gpu_at(g).clone()))
            .collect();
        admit_deployment(self.pipeline, self.deployment, &mut gpus)?;
        Ok(gpus)
    }

    /// Run the simulation at the given offered load (optimized engine).
    pub fn run(&self, offered_qps: f64) -> Result<SimReport, String> {
        let admitted = self.admit()?;
        // KV-cache headroom per GPU: capacity minus the static
        // weight/activation footprints the admit pass charged
        let kv_cap: Vec<f64> = admitted.iter().map(|g| g.mem_free()).collect();
        let cost = CostModel::new(self.cluster.gpu.clone());
        // per-GPU cost models only when a class departs from the base
        // spec — the homogeneous path keeps the single shared model
        let model_at = |g: usize| -> CostModel {
            let spec = self.cluster.gpu_at(g);
            if *spec == self.cluster.gpu {
                cost.clone()
            } else {
                CostModel::new(spec.clone())
            }
        };
        let mut bus = PcieBus::new(self.cluster.pcie.clone());
        let ipc = &self.cluster.ipc;
        let batch = self.deployment.batch.max(1) as usize;
        let batch_f = batch as f64;
        // arrival unit: one request = `batch` queries
        let n_requests = self.opts.queries.div_ceil(batch);
        let req_rate = offered_qps / batch as f64;
        let n_stages = self.pipeline.n_stages();
        let last_stage = n_stages - 1;

        // freeze every per-instance quantity the hot loop would
        // otherwise re-derive per event
        let mut instances: Vec<Inst> = self
            .deployment
            .placements
            .iter()
            .map(|p| {
                let stage = &self.pipeline.stages[p.stage];
                Inst {
                    stage: p.stage,
                    gpu: p.gpu,
                    queue: VecDeque::with_capacity(16),
                    busy: false,
                    exec_rid: 0,
                    cost: model_at(p.gpu).instance_cost_scaled(
                        stage,
                        batch as u32,
                        p.sm_frac,
                        self.cluster.scale_at(p.gpu),
                    ),
                    in_bytes_batch: stage.in_bytes_per_query * batch as f64,
                    out_bytes_batch: stage.out_bytes_per_query * batch as f64,
                    kv_bytes_batch: stage.mem_bytes_per_query * batch as f64,
                }
            })
            .collect();
        let mut by_stage: Vec<Vec<usize>> = vec![Vec::new(); n_stages];
        for (i, inst) in instances.iter().enumerate() {
            by_stage[inst.stage].push(i);
        }
        let mut ledgers: Vec<GpuLedger> = (0..self.cluster.num_gpus)
            .map(|_| GpuLedger::default())
            .collect();
        let mut kv_used = vec![0.0f64; self.cluster.num_gpus];
        let mut kv_peak = vec![0.0f64; self.cluster.num_gpus];

        // lazy open-loop arrivals: exactly one pending Arrival event at
        // a time; timestamps land in the arena as they are drawn
        let mut gen = PoissonArrivals::new(req_rate, self.opts.seed);
        let mut arrivals: Vec<f64> = Vec::with_capacity(n_requests);

        let mut heap: BinaryHeap<Event<Ev>> =
            BinaryHeap::with_capacity(instances.len() * 4 + 16);
        let mut seq = 0u64;
        let push = |heap: &mut BinaryHeap<Event<Ev>>, seq: &mut u64, t: f64, ev: Ev| {
            *seq += 1;
            heap.push(Event { t, seq: *seq, ev });
        };
        if n_requests > 0 {
            let t = gen.next_time();
            arrivals.push(t);
            push(&mut heap, &mut seq, t, Ev::Arrival { rid: 0 });
        }

        let mut hist = LatencyHistogram::new();
        let mut breakdown = TimeBreakdown::default();
        let mut stage_exec_sum = vec![0.0f64; n_stages];
        let mut stage_exec_n = vec![0u64; n_stages];
        let warmup = (n_requests as f64 * self.opts.warmup_frac) as u64;
        let mut completed = 0u64;
        let mut first_counted_t = f64::NAN;
        let mut last_t = 0.0f64;
        let mut rr_counters = vec![0usize; n_stages];

        // issue a request on `inst_id` if it is idle with queued work
        #[allow(clippy::too_many_arguments)]
        fn try_issue(
            inst_id: usize,
            now: f64,
            instances: &mut [Inst],
            ledgers: &mut [GpuLedger],
            bus: &mut PcieBus,
            batch_f: f64,
            heap: &mut BinaryHeap<Event<Ev>>,
            seq: &mut u64,
            breakdown: &mut TimeBreakdown,
            stage_exec_sum: &mut [f64],
            stage_exec_n: &mut [u64],
            kv_used: &mut [f64],
            kv_peak: &mut [f64],
            kv_cap: &[f64],
        ) {
            let push = |heap: &mut BinaryHeap<Event<Ev>>, seq: &mut u64, t: f64, ev: Ev| {
                *seq += 1;
                heap.push(Event { t, seq: *seq, ev });
            };
            let inst = &mut instances[inst_id];
            if inst.busy || inst.queue.is_empty() {
                return;
            }
            // KV gate, checked *before* popping: when the GPU's resident
            // KV bytes leave no room for this request's cache, the
            // request stays queued (the stall accrues as queue time) and
            // a later completion's release wakes this instance
            if inst.kv_bytes_batch > 0.0
                && kv_used[inst.gpu] + inst.kv_bytes_batch > kv_cap[inst.gpu]
            {
                return;
            }
            // one request (= `batch` queries) per execution
            let (rid, ready) = inst.queue.pop_front().unwrap();
            breakdown.queue_s += (now - ready) * batch_f;
            inst.busy = true;
            inst.exec_rid = rid;

            let gpu = inst.gpu;
            let stage_idx = inst.stage;
            let icost = inst.cost;
            let in_bytes = inst.in_bytes_batch;
            if inst.kv_bytes_batch > 0.0 {
                kv_used[gpu] += inst.kv_bytes_batch;
                if kv_used[gpu] > kv_peak[gpu] {
                    kv_peak[gpu] = kv_used[gpu];
                }
            }

            // stage-0 ingress crosses PCIe before the kernel runs
            let mut start = now;
            if stage_idx == 0 {
                let up = bus.begin_transfer(in_bytes);
                push(heap, seq, now + up, Ev::BusRelease);
                breakdown.upload_s += up * batch_f;
                start += up;
            }
            let others = ledgers[gpu].kernel_start(inst_id, icost.bw_demand);
            let dur = icost.duration_contended(others);
            stage_exec_sum[stage_idx] += dur;
            stage_exec_n[stage_idx] += 1;
            breakdown.exec_s += dur * batch_f;
            push(heap, seq, start + dur, Ev::ExecDone { inst: inst_id });
        }

        while let Some(Event { t: now, ev, .. }) = heap.pop() {
            last_t = now;
            match ev {
                Ev::Arrival { rid } => {
                    // keep the open loop primed: draw the next arrival
                    let next_rid = rid as usize + 1;
                    if next_rid < n_requests {
                        let t = gen.next_time();
                        arrivals.push(t);
                        push(&mut heap, &mut seq, t, Ev::Arrival { rid: next_rid as u32 });
                    }
                    let target = route_by(
                        &by_stage[0],
                        None,
                        &mut rr_counters[0],
                        |i| instances[i].queue.len() + instances[i].busy as usize,
                        |i| instances[i].gpu,
                    );
                    instances[target].queue.push_back((rid, now));
                    try_issue(
                        target, now, &mut instances, &mut ledgers, &mut bus, batch_f,
                        &mut heap, &mut seq, &mut breakdown,
                        &mut stage_exec_sum, &mut stage_exec_n,
                        &mut kv_used, &mut kv_peak, &kv_cap,
                    );
                }
                Ev::BusRelease => bus.end_transfer(),
                Ev::ExecDone { inst: inst_id } => {
                    let rid = instances[inst_id].exec_rid;
                    let stage_idx = instances[inst_id].stage;
                    let gpu = instances[inst_id].gpu;
                    let out_bytes = instances[inst_id].out_bytes_batch;
                    let kv_bytes = instances[inst_id].kv_bytes_batch;
                    ledgers[gpu].kernel_end(inst_id);
                    instances[inst_id].busy = false;
                    if kv_bytes > 0.0 {
                        kv_used[gpu] -= kv_bytes;
                    }
                    if stage_idx == last_stage {
                        // egress download crosses PCIe
                        let dl = bus.begin_transfer(out_bytes);
                        push(&mut heap, &mut seq, now + dl, Ev::BusRelease);
                        breakdown.download_s += dl * batch_f;
                        push(&mut heap, &mut seq, now + dl, Ev::Complete { rid });
                    } else {
                        let target = route_by(
                            &by_stage[stage_idx + 1],
                            Some(gpu),
                            &mut rr_counters[stage_idx + 1],
                            |i| instances[i].queue.len() + instances[i].busy as usize,
                            |i| instances[i].gpu,
                        );
                        let same_gpu = instances[target].gpu == gpu;
                        let hop =
                            hop_cost(self.deployment.comm, same_gpu, out_bytes, &mut bus, ipc);
                        if hop.uses_bus {
                            push(&mut heap, &mut seq, now + hop.duration_s, Ev::BusRelease);
                        }
                        breakdown.hop_s += hop.duration_s * batch_f;
                        push(
                            &mut heap, &mut seq, now + hop.duration_s,
                            Ev::Deliver { target, rid },
                        );
                    }
                    // instance freed: maybe issue the next request
                    try_issue(
                        inst_id, now, &mut instances, &mut ledgers, &mut bus, batch_f,
                        &mut heap, &mut seq, &mut breakdown,
                        &mut stage_exec_sum, &mut stage_exec_n,
                        &mut kv_used, &mut kv_peak, &kv_cap,
                    );
                    // the released KV bytes may unblock co-located
                    // instances stalled on the gate: wake them in
                    // instance-id order (deterministic). KV-free
                    // pipelines never enter this loop.
                    if kv_bytes > 0.0 {
                        for i in 0..instances.len() {
                            if instances[i].gpu == gpu && i != inst_id {
                                try_issue(
                                    i, now, &mut instances, &mut ledgers, &mut bus, batch_f,
                                    &mut heap, &mut seq, &mut breakdown,
                                    &mut stage_exec_sum, &mut stage_exec_n,
                                    &mut kv_used, &mut kv_peak, &kv_cap,
                                );
                            }
                        }
                    }
                }
                Ev::Deliver { target, rid } => {
                    instances[target].queue.push_back((rid, now));
                    try_issue(
                        target, now, &mut instances, &mut ledgers, &mut bus, batch_f,
                        &mut heap, &mut seq, &mut breakdown,
                        &mut stage_exec_sum, &mut stage_exec_n,
                        &mut kv_used, &mut kv_peak, &kv_cap,
                    );
                }
                Ev::Complete { rid } => {
                    completed += 1;
                    if completed > warmup {
                        if first_counted_t.is_nan() {
                            first_counted_t = now;
                        }
                        hist.record(now - arrivals[rid as usize]);
                    }
                }
            }
        }

        let span = (last_t - first_counted_t).max(1e-9);
        let counted = completed.saturating_sub(warmup);
        Ok(SimReport {
            achieved_qps: counted as f64 * batch as f64 / span,
            offered_qps,
            completed,
            hist,
            breakdown,
            stage_exec_mean_s: stage_exec_sum
                .iter()
                .zip(&stage_exec_n)
                .map(|(s, &n)| if n == 0 { 0.0 } else { s / n as f64 })
                .collect(),
            kv_peak_bytes: kv_peak,
        })
    }

    /// Run the simulation with the seed (reference) engine: per-event
    /// [`CostModel`] evaluation, materialized arrivals, vector-payload
    /// events. Slow but simple — kept as the golden oracle the optimized
    /// [`run`](Self::run) must match bit-for-bit, and as the baseline
    /// `benches/bench_sim.rs` measures speedups against.
    ///
    /// Compiled only for in-crate tests and under the `reference-engine`
    /// feature (the golden suite and the engine benches enable it), so
    /// ordinary builds carry no dead reference path to keep in sync.
    #[cfg(any(test, feature = "reference-engine"))]
    pub fn run_reference(&self, offered_qps: f64) -> Result<SimReport, String> {
        let mut gpus = self.admit()?;
        // KV-cache headroom per GPU after static admission — the same
        // quantities the optimized engine freezes
        let kv_cap: Vec<f64> = gpus.iter().map(|g| g.mem_free()).collect();
        let mut kv_used = vec![0.0f64; gpus.len()];
        let mut kv_peak = vec![0.0f64; gpus.len()];
        let cost = CostModel::new(self.cluster.gpu.clone());
        // per-instance (model, scale) for heterogeneous pools; on the
        // homogeneous base cluster every entry is the shared model at
        // scale 1.0 and the per-event calls below are unchanged
        let models: Vec<CostModel> = self
            .deployment
            .placements
            .iter()
            .map(|p| {
                let spec = self.cluster.gpu_at(p.gpu);
                if *spec == self.cluster.gpu {
                    cost.clone()
                } else {
                    CostModel::new(spec.clone())
                }
            })
            .collect();
        let scales: Vec<f64> = self
            .deployment
            .placements
            .iter()
            .map(|p| self.cluster.scale_at(p.gpu))
            .collect();
        let mut bus = PcieBus::new(self.cluster.pcie.clone());
        let ipc = &self.cluster.ipc;
        let batch = self.deployment.batch.max(1) as usize;
        // arrival unit: one request = `batch` queries
        let n_requests = self.opts.queries.div_ceil(batch);
        let req_rate = offered_qps / batch as f64;

        struct RefInstance {
            stage: usize,
            gpu: usize,
            sm_frac: f64,
            queue: VecDeque<(u32, f64)>,
            busy: bool,
            exec: Option<Vec<u32>>,
        }

        #[derive(Debug, Clone, PartialEq)]
        enum RefEv {
            Arrival { qid: u32 },
            ExecDone { inst: usize },
            BusRelease,
            XferDone { target: Option<usize>, qids: Vec<u32> },
        }

        let mut instances: Vec<RefInstance> = self
            .deployment
            .placements
            .iter()
            .map(|p| RefInstance {
                stage: p.stage,
                gpu: p.gpu,
                sm_frac: p.sm_frac,
                queue: VecDeque::new(),
                busy: false,
                exec: None,
            })
            .collect();
        let mut by_stage: Vec<Vec<usize>> = vec![Vec::new(); self.pipeline.n_stages()];
        for (i, inst) in instances.iter().enumerate() {
            by_stage[inst.stage].push(i);
        }

        // generate all request arrivals up front
        let arrivals: Vec<f64> =
            PoissonArrivals::new(req_rate, self.opts.seed).take_times(n_requests);

        let mut heap = BinaryHeap::with_capacity(n_requests * 6);
        let mut seq = 0u64;
        let push = |heap: &mut BinaryHeap<Event<RefEv>>, seq: &mut u64, t: f64, ev: RefEv| {
            *seq += 1;
            heap.push(Event { t, seq: *seq, ev });
        };
        for (qid, &t) in arrivals.iter().enumerate() {
            push(&mut heap, &mut seq, t, RefEv::Arrival { qid: qid as u32 });
        }

        let mut hist = LatencyHistogram::new();
        let mut breakdown = TimeBreakdown::default();
        let mut stage_exec_sum = vec![0.0f64; self.pipeline.n_stages()];
        let mut stage_exec_n = vec![0u64; self.pipeline.n_stages()];
        let warmup = (n_requests as f64 * self.opts.warmup_frac) as u64;
        let mut completed = 0u64;
        let mut first_counted_t = f64::NAN;
        let mut last_t = 0.0f64;
        let mut rr_counters = vec![0usize; self.pipeline.n_stages()];

        // issue a batch on `inst` if warranted; schedules events.
        #[allow(clippy::too_many_arguments)]
        fn try_issue(
            inst_id: usize,
            now: f64,
            instances: &mut [RefInstance],
            gpus: &mut [SimGpu],
            bus: &mut PcieBus,
            models: &[CostModel],
            scales: &[f64],
            pipeline: &Pipeline,
            batch: usize,
            heap: &mut BinaryHeap<Event<RefEv>>,
            seq: &mut u64,
            breakdown: &mut TimeBreakdown,
            stage_exec_sum: &mut [f64],
            stage_exec_n: &mut [u64],
            kv_used: &mut [f64],
            kv_peak: &mut [f64],
            kv_cap: &[f64],
        ) {
            let push = |heap: &mut BinaryHeap<Event<RefEv>>, seq: &mut u64, t: f64, ev: RefEv| {
                *seq += 1;
                heap.push(Event { t, seq: *seq, ev });
            };
            let inst = &mut instances[inst_id];
            if inst.busy || inst.queue.is_empty() {
                return;
            }
            // KV gate before popping (same semantics — and the same
            // `mem_bytes_per_query * batch` product — as the optimized
            // engine's frozen `kv_bytes_batch`)
            let kv_bytes = pipeline.stages[inst.stage].mem_bytes_per_query * batch as f64;
            if kv_bytes > 0.0 && kv_used[inst.gpu] + kv_bytes > kv_cap[inst.gpu] {
                return;
            }
            // one request (= `batch` queries) per execution
            let (rid, ready) = inst.queue.pop_front().unwrap();
            let qids = vec![rid];
            let n = batch;
            breakdown.queue_s += (now - ready) * n as f64;
            inst.busy = true;

            let stage = &pipeline.stages[inst.stage];
            let gpu = inst.gpu;
            let sm = inst.sm_frac;
            let stage_idx = inst.stage;
            if kv_bytes > 0.0 {
                kv_used[gpu] += kv_bytes;
                if kv_used[gpu] > kv_peak[gpu] {
                    kv_peak[gpu] = kv_used[gpu];
                }
            }

            // stage-0 ingress crosses PCIe before the kernel runs
            let mut start = now;
            if stage_idx == 0 {
                let bytes = stage.in_bytes_per_query * n as f64;
                let up = bus.begin_transfer(bytes);
                push(heap, seq, now + up, RefEv::BusRelease);
                breakdown.upload_s += up * n as f64;
                start += up;
            }
            let cost = &models[inst_id];
            let (demand, dur_of): (f64, _) = if scales[inst_id] == 1.0 {
                // seed path: per-event CostModel evaluation
                (cost.bw_demand(stage, n as u32, sm), None)
            } else {
                // heterogeneous class: the scaled frozen quantities are
                // the semantics (bit-identical to the optimized engine
                // by the instance-cost cache contract)
                let ic = cost.instance_cost_scaled(stage, n as u32, sm, scales[inst_id]);
                (ic.bw_demand, Some(ic))
            };
            let others = gpus[gpu].kernel_start(inst_id, demand);
            let dur = match dur_of {
                None => cost.duration_contended(stage, n as u32, sm, others),
                Some(ic) => ic.duration_contended(others),
            };
            stage_exec_sum[stage_idx] += dur;
            stage_exec_n[stage_idx] += 1;
            breakdown.exec_s += dur * n as f64;
            push(heap, seq, start + dur, RefEv::ExecDone { inst: inst_id });
            instances[inst_id].exec = Some(qids);
        }

        while let Some(Event { t: now, ev, .. }) = heap.pop() {
            last_t = now;
            match ev {
                RefEv::Arrival { qid } => {
                    let target = route_by(
                        &by_stage[0],
                        None,
                        &mut rr_counters[0],
                        |i| instances[i].queue.len() + instances[i].busy as usize,
                        |i| instances[i].gpu,
                    );
                    instances[target].queue.push_back((qid, now));
                    try_issue(
                        target, now, &mut instances, &mut gpus, &mut bus, &models, &scales,
                        self.pipeline, batch, &mut heap,
                        &mut seq, &mut breakdown, &mut stage_exec_sum, &mut stage_exec_n,
                        &mut kv_used, &mut kv_peak, &kv_cap,
                    );
                }
                RefEv::BusRelease => bus.end_transfer(),
                RefEv::ExecDone { inst: inst_id } => {
                    let qids = instances[inst_id].exec.take().unwrap_or_default();
                    let stage_idx = instances[inst_id].stage;
                    let gpu = instances[inst_id].gpu;
                    let kv_bytes =
                        self.pipeline.stages[stage_idx].mem_bytes_per_query * batch as f64;
                    gpus[gpu].kernel_end(inst_id);
                    instances[inst_id].busy = false;
                    if kv_bytes > 0.0 {
                        kv_used[gpu] -= kv_bytes;
                    }
                    let n = (qids.len() * batch) as f64;
                    let is_last = stage_idx + 1 == self.pipeline.n_stages();
                    if is_last {
                        // egress download crosses PCIe
                        let bytes =
                            self.pipeline.stages[stage_idx].out_bytes_per_query * n;
                        let dl = bus.begin_transfer(bytes);
                        push(&mut heap, &mut seq, now + dl, RefEv::BusRelease);
                        breakdown.download_s += dl * n;
                        push(
                            &mut heap, &mut seq, now + dl,
                            RefEv::XferDone { target: None, qids },
                        );
                    } else {
                        let target = route_by(
                            &by_stage[stage_idx + 1],
                            Some(gpu),
                            &mut rr_counters[stage_idx + 1],
                            |i| instances[i].queue.len() + instances[i].busy as usize,
                            |i| instances[i].gpu,
                        );
                        let same_gpu = instances[target].gpu == gpu;
                        let bytes =
                            self.pipeline.stages[stage_idx].out_bytes_per_query * n;
                        let hop = hop_cost(self.deployment.comm, same_gpu, bytes, &mut bus, ipc);
                        if hop.uses_bus {
                            push(&mut heap, &mut seq, now + hop.duration_s, RefEv::BusRelease);
                        }
                        breakdown.hop_s += hop.duration_s * n;
                        push(
                            &mut heap, &mut seq, now + hop.duration_s,
                            RefEv::XferDone { target: Some(target), qids },
                        );
                    }
                    // instance freed: maybe issue the next batch
                    try_issue(
                        inst_id, now, &mut instances, &mut gpus, &mut bus, &models, &scales,
                        self.pipeline, batch, &mut heap,
                        &mut seq, &mut breakdown, &mut stage_exec_sum, &mut stage_exec_n,
                        &mut kv_used, &mut kv_peak, &kv_cap,
                    );
                    // wake co-located instances the released KV bytes
                    // may unblock, in instance-id order (mirrors the
                    // optimized engine exactly)
                    if kv_bytes > 0.0 {
                        for i in 0..instances.len() {
                            if instances[i].gpu == gpu && i != inst_id {
                                try_issue(
                                    i, now, &mut instances, &mut gpus, &mut bus, &models,
                                    &scales, self.pipeline, batch, &mut heap, &mut seq,
                                    &mut breakdown, &mut stage_exec_sum, &mut stage_exec_n,
                                    &mut kv_used, &mut kv_peak, &kv_cap,
                                );
                            }
                        }
                    }
                }
                RefEv::XferDone { target, qids } => match target {
                    Some(t_inst) => {
                        for qid in qids {
                            instances[t_inst].queue.push_back((qid, now));
                        }
                        try_issue(
                            t_inst, now, &mut instances, &mut gpus, &mut bus, &models, &scales,
                            self.pipeline, batch, &mut heap,
                            &mut seq, &mut breakdown, &mut stage_exec_sum, &mut stage_exec_n,
                            &mut kv_used, &mut kv_peak, &kv_cap,
                        );
                    }
                    None => {
                        for rid in qids {
                            completed += 1;
                            if completed > warmup {
                                if first_counted_t.is_nan() {
                                    first_counted_t = now;
                                }
                                hist.record(now - arrivals[rid as usize]);
                            }
                        }
                    }
                },
            }
        }

        let span = (last_t - first_counted_t).max(1e-9);
        let counted = completed.saturating_sub(warmup);
        Ok(SimReport {
            achieved_qps: counted as f64 * batch as f64 / span,
            offered_qps,
            completed,
            hist,
            breakdown,
            stage_exec_mean_s: stage_exec_sum
                .iter()
                .zip(&stage_exec_n)
                .map(|(s, &n)| if n == 0 { 0.0 } else { s / n as f64 })
                .collect(),
            kv_peak_bytes: kv_peak,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::suite::real;

    fn simple_deployment(comm: CommMode) -> Deployment {
        Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
                InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.5 },
            ],
            batch: 16,
            comm,
        }
    }

    #[test]
    fn all_queries_complete_at_low_load() {
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::GlobalIpc);
        let sim = Simulator::new(&p, &c, &d, SimOptions { queries: 1_000, ..Default::default() });
        let r = sim.run(50.0).unwrap();
        // completion unit is the request (= batch of 16 queries)
        assert_eq!(r.completed, 1_000 / 16 + 1);
        assert!(r.p99() > 0.0);
        assert!(r.p99() < 10.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = real::text_to_text();
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::MainMemory);
        let o = SimOptions { queries: 500, ..Default::default() };
        let a = Simulator::new(&p, &c, &d, o.clone()).run(40.0).unwrap();
        let b = Simulator::new(&p, &c, &d, o).run(40.0).unwrap();
        assert_eq!(a.p99(), b.p99());
        assert_eq!(a.completed, b.completed);
    }

    #[test]
    fn latency_grows_with_load() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::GlobalIpc);
        let o = SimOptions { queries: 2_000, ..Default::default() };
        let lo = Simulator::new(&p, &c, &d, o.clone()).run(20.0).unwrap();
        let hi = Simulator::new(&p, &c, &d, o).run(2_000.0).unwrap();
        assert!(
            hi.p99() > lo.p99(),
            "overload p99 {} must exceed light-load p99 {}",
            hi.p99(),
            lo.p99()
        );
    }

    #[test]
    fn ipc_beats_main_memory_on_image_pipeline() {
        // Fig 5/11: heavy payloads + same GPU ⇒ IPC reduces latency.
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let o = SimOptions { queries: 2_000, ..Default::default() };
        let mm = Simulator::new(&p, &c, &simple_deployment(CommMode::MainMemory), o.clone())
            .run(60.0)
            .unwrap();
        let gi = Simulator::new(&p, &c, &simple_deployment(CommMode::GlobalIpc), o)
            .run(60.0)
            .unwrap();
        assert!(
            gi.hist.mean() < mm.hist.mean(),
            "ipc mean {} vs mm mean {}",
            gi.hist.mean(),
            mm.hist.mean()
        );
        assert!(gi.breakdown.hop_s < mm.breakdown.hop_s);
    }

    #[test]
    fn admit_rejects_oversubscription() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let d = Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.8 },
                InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.5 },
            ],
            batch: 8,
            comm: CommMode::GlobalIpc,
        };
        assert!(Simulator::new(&p, &c, &d, SimOptions::default()).admit().is_err());
    }

    #[test]
    fn admit_rejects_missing_stage() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let d = Deployment {
            placements: vec![InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 }],
            batch: 8,
            comm: CommMode::GlobalIpc,
        };
        assert!(Simulator::new(&p, &c, &d, SimOptions::default()).admit().is_err());
    }

    #[test]
    fn breakdown_accounts_communication() {
        let p = real::img_to_img();
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::MainMemory);
        let r = Simulator::new(&p, &c, &d, SimOptions { queries: 1_000, ..Default::default() })
            .run(40.0)
            .unwrap();
        let b = &r.breakdown;
        assert!(b.upload_s > 0.0 && b.hop_s > 0.0 && b.download_s > 0.0);
        // Fig 5 decomposes processing vs data transfer (queueing aside):
        // with main-memory comm the transfer share is large.
        let frac = b.comm_total() / (b.comm_total() + b.exec_s);
        assert!(frac > 0.15, "comm fraction {frac}");
    }

    #[test]
    fn hetero_class_speeds_up_and_engines_agree() {
        use crate::config::GpuClass;
        let p = real::img_to_text();
        let base = ClusterSpec::two_2080ti();
        // same hardware, but GPU 1 runs stages at 0.5× the service time
        let fast = ClusterSpec {
            classes: vec![
                GpuClass::scaled(base.gpu.clone(), 1, 1.0),
                GpuClass::scaled(base.gpu.clone(), 1, 0.5),
            ],
            ..base.clone()
        };
        let d = Deployment {
            placements: vec![
                InstancePlacement { stage: 0, gpu: 1, sm_frac: 0.5 },
                InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.5 },
            ],
            batch: 16,
            comm: CommMode::GlobalIpc,
        };
        let o = SimOptions { queries: 800, ..Default::default() };
        let slow_run = Simulator::new(&p, &base, &d, o.clone()).run(80.0).unwrap();
        let fast_sim = Simulator::new(&p, &fast, &d, o);
        let fast_run = fast_sim.run(80.0).unwrap();
        assert!(
            fast_run.hist.mean() < slow_run.hist.mean(),
            "0.5× service time must lower mean latency: {} vs {}",
            fast_run.hist.mean(),
            slow_run.hist.mean()
        );
        // optimized and reference engines agree bit-for-bit on the
        // heterogeneous cluster too
        let fast_ref = fast_sim.run_reference(80.0).unwrap();
        assert_eq!(fast_run.completed, fast_ref.completed);
        assert_eq!(fast_run.p99().to_bits(), fast_ref.p99().to_bits());
        assert_eq!(
            fast_run.breakdown.exec_s.to_bits(),
            fast_ref.breakdown.exec_s.to_bits()
        );
    }

    #[test]
    fn explicit_identity_classes_are_bit_identical() {
        use crate::config::GpuClass;
        let p = real::img_to_text();
        let base = ClusterSpec::two_2080ti();
        let tagged = ClusterSpec {
            classes: vec![GpuClass::scaled(base.gpu.clone(), 2, 1.0)],
            ..base.clone()
        };
        let d = simple_deployment(CommMode::GlobalIpc);
        let o = SimOptions { queries: 800, ..Default::default() };
        let a = Simulator::new(&p, &base, &d, o.clone()).run(120.0).unwrap();
        let b = Simulator::new(&p, &tagged, &d, o).run(120.0).unwrap();
        assert_eq!(a.p99().to_bits(), b.p99().to_bits());
        assert_eq!(a.breakdown.exec_s.to_bits(), b.breakdown.exec_s.to_bits());
        assert_eq!(a.completed, b.completed);
    }

    #[test]
    fn kv_residency_is_tracked_and_engines_agree() {
        let p = crate::llm::pipeline(&crate::llm::LlmParams::default());
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::GlobalIpc);
        let o = SimOptions { queries: 800, ..Default::default() };
        let sim = Simulator::new(&p, &c, &d, o);
        let opt = sim.run(40.0).unwrap();
        let refr = sim.run_reference(40.0).unwrap();
        // both engines observe the identical trajectory, KV included
        assert_eq!(opt.completed, refr.completed);
        assert_eq!(opt.p99().to_bits(), refr.p99().to_bits());
        for (a, b) in opt.kv_peak_bytes.iter().zip(&refr.kv_peak_bytes) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // both stages sit on GPU 0: its peak covers at least one
        // request's prefill cache and never exceeds the free memory
        let free = sim.admit().unwrap()[0].mem_free();
        assert!(opt.kv_peak_bytes[0] >= p.stages[0].mem_bytes_per_query * 16.0);
        assert!(opt.kv_peak_bytes[0] <= free);
        assert_eq!(opt.kv_peak_bytes[1], 0.0);
        // a KV-free pipeline reports all-zero peaks
        let vision = real::img_to_text();
        let v = Simulator::new(&vision, &c, &d, SimOptions { queries: 400, ..Default::default() })
            .run(40.0)
            .unwrap();
        assert!(v.kv_peak_bytes.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn kv_capacity_stalls_issue_and_raises_latency() {
        // KV budget so tight only one request's cache fits per GPU:
        // co-located instances must serialize on the KV gate
        let params = crate::llm::LlmParams {
            prompt_tokens: 512,
            output_tokens: 128,
            kv_bytes_per_token: 500_000,
        };
        let tight = crate::llm::pipeline(&params);
        let roomy = crate::llm::pipeline(&crate::llm::LlmParams {
            kv_bytes_per_token: 65_536,
            ..params
        });
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::GlobalIpc);
        let o = SimOptions { queries: 800, ..Default::default() };
        let tight_run = Simulator::new(&tight, &c, &d, o.clone()).run(60.0).unwrap();
        let roomy_run = Simulator::new(&roomy, &c, &d, o).run(60.0).unwrap();
        // the decode stall surfaces as queueing, so the tail inflates
        assert!(
            tight_run.p99() > roomy_run.p99(),
            "tight KV p99 {} must exceed roomy {}",
            tight_run.p99(),
            roomy_run.p99()
        );
        assert!(tight_run.breakdown.queue_s > roomy_run.breakdown.queue_s);
        // everything still completes (the gate stalls, never deadlocks)
        assert_eq!(tight_run.completed, roomy_run.completed);
    }

    #[test]
    fn optimized_matches_reference_smoke() {
        // the exhaustive version lives in tests/golden_engine.rs; this
        // in-module check keeps the contract visible next to the code
        let p = real::img_to_text();
        let c = ClusterSpec::two_2080ti();
        let d = simple_deployment(CommMode::GlobalIpc);
        let o = SimOptions { queries: 800, ..Default::default() };
        let sim = Simulator::new(&p, &c, &d, o);
        let opt = sim.run(120.0).unwrap();
        let refr = sim.run_reference(120.0).unwrap();
        assert_eq!(opt.completed, refr.completed);
        assert_eq!(opt.p99().to_bits(), refr.p99().to_bits());
        assert_eq!(opt.breakdown.exec_s.to_bits(), refr.breakdown.exec_s.to_bits());
    }
}
