//! Kernel cost model: how long one batched microservice execution takes
//! on a spatial-multitasking GPU, given its SM quota and the runtime
//! global-memory-bandwidth contention.
//!
//! Roofline + Amdahl:
//!   t_compute(p) = (FLOPs / G) · (serial + (1 − serial)/p)
//!   t_mem        = HBM bytes / BW_peak, inflated by the contention
//!                  factor max(1, Σ demands / BW_peak)
//!   t            = launch + max(t_compute, t_mem)
//!
//! This produces exactly the paper's observed shapes: Fig 3a (compute
//! kernels scale with SMs until the serial fraction saturates), Fig 3b
//! (memory kernels stop scaling once bandwidth-bound), and Fig 4b (the
//! unmanaged-bandwidth slowdown that breaks the balanced deployment).

use crate::config::GpuSpec;
use crate::suite::StageProfile;

/// SM share needed to saturate global-memory bandwidth: a kernel on
/// fraction `p` of the SMs can draw at most `min(1, BW_SATURATION·p)` of
/// the peak bandwidth (a 2080Ti needs roughly 40% of its SMs in flight
/// to saturate HBM — Fig 3b's plateau point).
pub const BW_SATURATION: f64 = 2.5;

/// The serial (non-SM-parallel) portion of a kernel runs at this
/// fraction of peak throughput regardless of the SM quota — this is why
/// the sequential language models (LSTM decode loops) cannot reach peak
/// even on a whole GPU (Fig 4a: img-to-text is stage-2 bound).
pub const SERIAL_EFF: f64 = 1.0 / 6.0;

/// Sub-saturation interference: co-running kernels degrade each other
/// through the shared L2/memory hierarchy even before raw bandwidth
/// saturates (the Fig 4b effect that breaks contention-oblivious
/// balanced deployments). Applied per unit of co-runner demand.
pub const CACHE_INTERFERENCE: f64 = 0.25;
pub const MEM_INTERFERENCE: f64 = 0.20;

/// Cost model bound to one GPU model.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub gpu: GpuSpec,
}

impl CostModel {
    pub fn new(gpu: GpuSpec) -> Self {
        CostModel { gpu }
    }

    /// Compute-side time at SM fraction `p` (Amdahl-scaled; the serial
    /// portion runs at SERIAL_EFF of peak regardless of quota).
    pub fn compute_time(&self, stage: &StageProfile, batch: u32, p: f64) -> f64 {
        let p = p.clamp(1.0 / self.gpu.sms as f64, 1.0);
        stage.flops(batch) / self.gpu.flops_per_sec()
            * (stage.serial_frac / SERIAL_EFF + (1.0 - stage.serial_frac) / p)
    }

    /// Memory-side time on a solo run: the achievable bandwidth scales
    /// with the SM share until saturation (BW_SATURATION).
    pub fn mem_time_solo(&self, stage: &StageProfile, batch: u32, p: f64) -> f64 {
        let p = p.clamp(1.0 / self.gpu.sms as f64, 1.0);
        let achievable = self.gpu.mem_bw * (BW_SATURATION * p).min(1.0);
        stage.hbm_bytes(batch) / achievable
    }

    /// Solo-run duration (no co-runners), the quantity the paper
    /// profiles offline (§VII-A).
    pub fn duration_solo(&self, stage: &StageProfile, batch: u32, p: f64) -> f64 {
        self.gpu.launch_overhead_s
            + self
                .compute_time(stage, batch, p)
                .max(self.mem_time_solo(stage, batch, p))
    }

    /// Intrinsic global-memory-bandwidth demand rate (bytes/s) of the
    /// kernel while it runs — what g(p) in Table II predicts.
    pub fn bw_demand(&self, stage: &StageProfile, batch: u32, p: f64) -> f64 {
        stage.hbm_bytes(batch) / self.duration_solo(stage, batch, p)
    }

    /// Duration under contention: `other_demand` is the sum of the
    /// bandwidth demand rates of the co-running kernels on this GPU.
    pub fn duration_contended(
        &self,
        stage: &StageProfile,
        batch: u32,
        p: f64,
        other_demand: f64,
    ) -> f64 {
        let own = self.bw_demand(stage, batch, p);
        let total = own + other_demand;
        // congestion in [0, 1]: how loaded the memory system is with
        // co-runner traffic (sub-saturation interference input)
        let cong = (other_demand / self.gpu.mem_bw).min(1.0);
        let sat_factor = (total / self.gpu.mem_bw).max(1.0);
        let t_c = self.compute_time(stage, batch, p) * (1.0 + CACHE_INTERFERENCE * cong);
        let t_m = self.mem_time_solo(stage, batch, p)
            * sat_factor
            * (1.0 + MEM_INTERFERENCE * cong);
        self.gpu.launch_overhead_s + t_c.max(t_m)
    }

    /// Solo throughput (queries/s) of one instance — f(p) in Table II.
    pub fn throughput_solo(&self, stage: &StageProfile, batch: u32, p: f64) -> f64 {
        batch as f64 / self.duration_solo(stage, batch, p)
    }

    /// Precompute the per-instance cost quantities for an instance whose
    /// (stage, batch, SM quota) are fixed for the lifetime of a
    /// simulation — the engine's hot path then pays only the contention
    /// terms per kernel launch instead of re-deriving the roofline and
    /// Amdahl quantities on every event.
    ///
    /// Contract: [`InstanceCost::duration_contended`] is bit-identical
    /// to [`CostModel::duration_contended`] for the same inputs (the
    /// golden-equivalence tests depend on this).
    pub fn instance_cost(&self, stage: &StageProfile, batch: u32, p: f64) -> InstanceCost {
        InstanceCost {
            launch_s: self.gpu.launch_overhead_s,
            mem_bw: self.gpu.mem_bw,
            compute_time_s: self.compute_time(stage, batch, p),
            mem_time_solo_s: self.mem_time_solo(stage, batch, p),
            bw_demand: self.bw_demand(stage, batch, p),
        }
    }

    /// [`instance_cost`](Self::instance_cost) on a GPU whose per-stage
    /// service times run at `scale`× this model's (a heterogeneous-pool
    /// class's `compute_scale`; < 1 = faster). Compute and memory times
    /// scale directly; the bandwidth demand rate is re-derived from the
    /// scaled solo duration so the kernel still moves the same bytes
    /// over its (shorter or longer) lifetime. `scale == 1.0` returns
    /// exactly `instance_cost` — the homogeneous bit-identity guard.
    pub fn instance_cost_scaled(
        &self,
        stage: &StageProfile,
        batch: u32,
        p: f64,
        scale: f64,
    ) -> InstanceCost {
        if scale == 1.0 {
            return self.instance_cost(stage, batch, p);
        }
        let compute_time_s = self.compute_time(stage, batch, p) * scale;
        let mem_time_solo_s = self.mem_time_solo(stage, batch, p) * scale;
        let duration_solo = self.gpu.launch_overhead_s + compute_time_s.max(mem_time_solo_s);
        InstanceCost {
            launch_s: self.gpu.launch_overhead_s,
            mem_bw: self.gpu.mem_bw,
            compute_time_s,
            mem_time_solo_s,
            bw_demand: stage.hbm_bytes(batch) / duration_solo,
        }
    }
}

/// Frozen cost quantities of one placed instance (fixed stage, batch
/// size, and SM quota). Built once per simulation by
/// [`CostModel::instance_cost`]; evaluated per kernel launch.
#[derive(Debug, Clone, Copy)]
pub struct InstanceCost {
    pub launch_s: f64,
    pub mem_bw: f64,
    /// Amdahl-scaled compute time at the instance's quota.
    pub compute_time_s: f64,
    /// Solo memory-side time at the instance's quota.
    pub mem_time_solo_s: f64,
    /// Intrinsic bandwidth demand rate (bytes/s) while running.
    pub bw_demand: f64,
}

impl InstanceCost {
    /// Same expression tree as [`CostModel::duration_contended`], with
    /// the quota-dependent factors taken from the cache — identical
    /// floating-point operations in identical order, so the result is
    /// bit-for-bit the value the per-event path computes.
    #[inline]
    pub fn duration_contended(&self, other_demand: f64) -> f64 {
        let total = self.bw_demand + other_demand;
        let cong = (other_demand / self.mem_bw).min(1.0);
        let sat_factor = (total / self.mem_bw).max(1.0);
        let t_c = self.compute_time_s * (1.0 + CACHE_INTERFERENCE * cong);
        let t_m = self.mem_time_solo_s * sat_factor * (1.0 + MEM_INTERFERENCE * cong);
        self.launch_s + t_c.max(t_m)
    }

    /// Solo duration (no co-runners) from the cached quantities.
    #[inline]
    pub fn duration_solo(&self) -> f64 {
        self.launch_s + self.compute_time_s.max(self.mem_time_solo_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suite::artifact;
    use crate::util::testkit;

    fn model() -> CostModel {
        CostModel::new(crate::config::GpuSpec::rtx2080ti())
    }

    #[test]
    fn compute_kernel_scales_with_sms_then_saturates() {
        // Fig 3a: more SMs help compute kernels, sublinearly.
        let m = model();
        let c3 = artifact::compute(3);
        let t10 = m.duration_solo(&c3, 32, 0.10);
        let t50 = m.duration_solo(&c3, 32, 0.50);
        let t100 = m.duration_solo(&c3, 32, 1.00);
        assert!(t10 > 2.0 * t50, "t10={t10} t50={t50}");
        assert!(t50 > t100);
        // Amdahl: speedup 10%→100% stays below the 10× ideal
        assert!(t10 / t100 < 9.9);
    }

    #[test]
    fn memory_kernel_stops_scaling() {
        // Fig 3b: memory-bound kernels hit the bandwidth roof.
        let m = model();
        let m3 = artifact::memory(3);
        let t50 = m.duration_solo(&m3, 32, 0.50);
        let t100 = m.duration_solo(&m3, 32, 1.00);
        testkit::assert_close(t50, t100, 0.05, 0.0);
    }

    #[test]
    fn contention_inflates_memory_bound_kernels() {
        let m = model();
        let m2 = artifact::memory(2);
        let solo = m.duration_solo(&m2, 32, 0.5);
        // co-runners demanding 1.5× the peak bandwidth
        let contended = m.duration_contended(&m2, 32, 0.5, 1.5 * m.gpu.mem_bw);
        assert!(contended > 1.5 * solo, "solo={solo} contended={contended}");
        // compute-bound kernels see only the mild cache-interference
        // term below the bandwidth roof (<= CACHE_INTERFERENCE)
        let c3 = artifact::compute(3);
        let c_solo = m.duration_solo(&c3, 32, 1.0);
        let c_cont = m.duration_contended(&c3, 32, 1.0, 0.2 * m.gpu.mem_bw);
        assert!(c_cont > c_solo, "some interference must show");
        assert!(c_cont < c_solo * (1.0 + CACHE_INTERFERENCE), "bounded");
    }

    #[test]
    fn zero_contention_matches_solo() {
        let m = model();
        let s = artifact::compute(2);
        for p in [0.1, 0.35, 1.0] {
            testkit::assert_close(
                m.duration_contended(&s, 16, p, 0.0),
                m.duration_solo(&s, 16, p),
                1e-12,
                0.0,
            );
        }
    }

    #[test]
    fn bw_demand_never_exceeds_peak() {
        let m = model();
        for level in 1..=3 {
            for p in [0.1, 0.5, 1.0] {
                for batch in [8, 64] {
                    let d = m.bw_demand(&artifact::memory(level), batch, p);
                    assert!(d <= m.gpu.mem_bw * 1.0001, "demand {d}");
                }
            }
        }
    }

    #[test]
    fn throughput_monotone_in_quota() {
        let m = model();
        let c1 = artifact::compute(1);
        let mut prev = 0.0;
        for i in 1..=10 {
            let f = m.throughput_solo(&c1, 32, i as f64 / 10.0);
            assert!(f >= prev);
            prev = f;
        }
    }

    #[test]
    fn instance_cost_cache_is_bit_exact() {
        // The engine's per-instance cache must reproduce the per-event
        // CostModel path bit-for-bit, including the contention terms.
        let m = model();
        crate::util::testkit::forall(17, 400, |r| {
            (
                r.range(1, 3) as u32,
                r.range(1, 3) as u32,
                1 + r.below(256) as u32,
                r.range_f64(0.01, 1.0),
                r.range_f64(0.0, 2.0e12),
            )
        }, |&(lvl, mem_lvl, batch, p, other)| {
            for stage in [artifact::compute(lvl), artifact::memory(mem_lvl)] {
                let cached = m.instance_cost(&stage, batch, p);
                let a = cached.duration_contended(other);
                let b = m.duration_contended(&stage, batch, p, other);
                if a.to_bits() != b.to_bits() {
                    return false;
                }
                if cached.duration_solo().to_bits()
                    != m.duration_solo(&stage, batch, p).to_bits()
                {
                    return false;
                }
                if cached.bw_demand.to_bits() != m.bw_demand(&stage, batch, p).to_bits() {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn scaled_instance_cost_identity_and_monotone() {
        let m = model();
        let s = artifact::compute(2);
        // scale 1.0 is bit-identical to the unscaled cache
        let a = m.instance_cost(&s, 32, 0.4);
        let b = m.instance_cost_scaled(&s, 32, 0.4, 1.0);
        assert_eq!(a.duration_contended(1e10).to_bits(), b.duration_contended(1e10).to_bits());
        assert_eq!(a.bw_demand.to_bits(), b.bw_demand.to_bits());
        // a faster class (scale < 1) finishes sooner and, moving the
        // same bytes in less time, demands more bandwidth
        let fast = m.instance_cost_scaled(&s, 32, 0.4, 0.5);
        assert!(fast.duration_solo() < a.duration_solo());
        assert!(fast.bw_demand > a.bw_demand);
        let slow = m.instance_cost_scaled(&s, 32, 0.4, 2.0);
        assert!(slow.duration_solo() > a.duration_solo());
    }

    #[test]
    fn duration_positive_and_finite_property() {
        let m = model();
        crate::util::testkit::forall(11, 300, |r| {
            (
                r.range(1, 3) as u32,
                1 + r.below(512) as u32,
                r.range_f64(0.01, 1.0),
                r.range_f64(0.0, 2.0e12),
            )
        }, |&(lvl, batch, p, other)| {
            let t = m.duration_contended(&artifact::compute(lvl), batch, p, other);
            t.is_finite() && t > 0.0
        });
    }
}
