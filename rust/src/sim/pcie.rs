//! PCIe bus contention model (§VI-A).
//!
//! Each in-flight memcpy stream sustains at most `per_stream_bw`
//! (3,150 MB/s for pageable memory); the bus as a whole sustains
//! `effective_bw` (12,160 MB/s). With `k` concurrent streams, each gets
//! `min(per_stream_bw, effective_bw / k)` — so up to ⌊12160/3150⌋ = 3
//! streams run at full speed and further streams contend (Fig 9).
//!
//! Rates are evaluated at transfer start (start-time approximation); the
//! engine registers/unregisters streams around each transfer.

use crate::config::PcieSpec;

/// Mutable bus state owned by the simulation engine.
#[derive(Debug, Clone)]
pub struct PcieBus {
    spec: PcieSpec,
    active_streams: u32,
}

impl PcieBus {
    pub fn new(spec: PcieSpec) -> Self {
        PcieBus { spec, active_streams: 0 }
    }

    pub fn active_streams(&self) -> u32 {
        self.active_streams
    }

    /// Per-stream rate if one more stream joined right now.
    pub fn rate_with_one_more(&self) -> f64 {
        let k = (self.active_streams + 1) as f64;
        self.spec.per_stream_bw.min(self.spec.effective_bw / k)
    }

    /// Begin a transfer of `bytes`; returns its duration in seconds.
    /// Caller must `end_transfer()` when the completion event fires.
    pub fn begin_transfer(&mut self, bytes: f64) -> f64 {
        let rate = self.rate_with_one_more();
        self.active_streams += 1;
        self.spec.setup_s + bytes / rate
    }

    pub fn end_transfer(&mut self) {
        debug_assert!(self.active_streams > 0, "unbalanced end_transfer");
        self.active_streams = self.active_streams.saturating_sub(1);
    }

    /// Duration a transfer *would* take right now, without registering.
    pub fn probe_transfer(&self, bytes: f64) -> f64 {
        self.spec.setup_s + bytes / self.rate_with_one_more()
    }

    pub fn spec(&self) -> &PcieSpec {
        &self.spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit;

    fn bus() -> PcieBus {
        PcieBus::new(PcieSpec::default())
    }

    #[test]
    fn solo_stream_runs_at_per_stream_rate() {
        let mut b = bus();
        let five_gb = 5.0e9;
        let t = b.begin_transfer(five_gb);
        // paper: a single pageable memcpy sustains 3,150 MB/s
        testkit::assert_close(t, five_gb / 3.150e9, 0.01, 0.0);
    }

    #[test]
    fn contention_knee_at_four_streams() {
        // Fig 9: transfer time flat up to 3 instances, grows beyond.
        let mut b = bus();
        let bytes = 5.0e9;
        let mut times = Vec::new();
        for _ in 0..6 {
            times.push(b.begin_transfer(bytes));
        }
        testkit::assert_close(times[0], times[2], 0.01, 0.0); // 1..3 equal
        assert!(times[3] > times[2] * 1.02, "4th stream must contend");
        assert!(times[5] > times[4]); // monotone under load
    }

    #[test]
    fn end_transfer_restores_rate() {
        let mut b = bus();
        for _ in 0..5 {
            b.begin_transfer(1.0e9);
        }
        let congested = b.probe_transfer(1.0e9);
        for _ in 0..5 {
            b.end_transfer();
        }
        assert_eq!(b.active_streams(), 0);
        assert!(b.probe_transfer(1.0e9) < congested);
    }

    #[test]
    fn aggregate_rate_capped_at_effective_bw() {
        let mut b = bus();
        for _ in 0..10 {
            b.begin_transfer(1.0);
        }
        let per = b.spec().effective_bw / 10.0;
        testkit::assert_close(b.rate_with_one_more(), b.spec().effective_bw / 11.0, 1e-9, 0.0);
        assert!(per < b.spec().per_stream_bw);
    }
}
