//! Inter-microservice communication mechanisms (§VI).
//!
//! Two channel implementations:
//!
//! * [`CommMode::MainMemory`] — the default CUDA path (Fig 8a): the
//!   producer copies device→host, the consumer copies host→device. Both
//!   copies cross the contended PCIe bus, and the payload is resident
//!   twice in global memory.
//! * [`CommMode::GlobalIpc`] — Camelot's mechanism (Fig 8b/10): the
//!   producer passes an 8-byte CUDA-IPC handle; the consumer maps the
//!   producer's buffer directly. No bulk copy, a small fixed
//!   probe/transfer/decode overhead per message, and a one-time channel
//!   setup (~1 ms). Same-GPU only — cross-GPU hops always fall back to
//!   the main-memory path (§VI-B last paragraph).

use crate::config::IpcSpec;
use crate::sim::pcie::PcieBus;

/// Which mechanism a deployment uses for same-GPU hops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommMode {
    MainMemory,
    GlobalIpc,
}

/// Cost of one hop, already resolved against bus state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HopCost {
    /// Wall-clock seconds the transfer takes.
    pub duration_s: f64,
    /// Whether a PCIe stream was registered (caller must release it via
    /// `PcieBus::end_transfer` when the hop completes).
    pub uses_bus: bool,
    /// Extra global-memory bytes the payload occupies at the receiver
    /// (a second copy under MainMemory; none under IPC).
    pub receiver_copy_bytes: f64,
}

/// Resolve the cost of moving `bytes` from stage i to stage i+1.
///
/// `same_gpu` is whether both instances share a device. Registers a bus
/// stream for bus-crossing hops (start-time rate approximation, like all
/// bus transfers in the engine).
pub fn hop_cost(
    mode: CommMode,
    same_gpu: bool,
    bytes: f64,
    bus: &mut PcieBus,
    ipc: &IpcSpec,
) -> HopCost {
    match (mode, same_gpu) {
        (CommMode::GlobalIpc, true) => HopCost {
            // handle probe/transfer/decode only — payload never moves
            duration_s: ipc.per_msg_s,
            uses_bus: false,
            receiver_copy_bytes: ipc.handle_bytes as f64,
        },
        _ => {
            // device→host then host→device: 2× payload over the bus.
            // Modeled as one stream occupying the bus for both copies.
            let duration = bus.begin_transfer(2.0 * bytes);
            HopCost {
                duration_s: duration,
                uses_bus: true,
                receiver_copy_bytes: bytes,
            }
        }
    }
}

/// Fig 11 exact analytic comparison (uncontended bus): communication
/// time for one payload under both mechanisms.
pub fn fig11_point(bytes: f64, bus: &PcieBus, ipc: &IpcSpec) -> (f64, f64) {
    let main_mem = 2.0 * (bus.spec().setup_s + bytes / bus.spec().per_stream_bw);
    let global_ipc = ipc.per_msg_s;
    (main_mem, global_ipc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{IpcSpec, PcieSpec};

    fn setup() -> (PcieBus, IpcSpec) {
        (PcieBus::new(PcieSpec::default()), IpcSpec::default())
    }

    #[test]
    fn ipc_same_gpu_is_constant_time() {
        let (mut bus, ipc) = setup();
        let small = hop_cost(CommMode::GlobalIpc, true, 2.0, &mut bus, &ipc);
        let large = hop_cost(CommMode::GlobalIpc, true, 256e6, &mut bus, &ipc);
        assert_eq!(small.duration_s, large.duration_s);
        assert!(!small.uses_bus);
        assert_eq!(small.receiver_copy_bytes, 8.0);
        assert_eq!(bus.active_streams(), 0);
    }

    #[test]
    fn ipc_cross_gpu_falls_back_to_main_memory() {
        let (mut bus, ipc) = setup();
        let hop = hop_cost(CommMode::GlobalIpc, false, 1e6, &mut bus, &ipc);
        assert!(hop.uses_bus);
        assert_eq!(bus.active_streams(), 1);
        assert_eq!(hop.receiver_copy_bytes, 1e6);
    }

    #[test]
    fn main_memory_pays_double_copy() {
        let (mut bus, ipc) = setup();
        let hop = hop_cost(CommMode::MainMemory, true, 10e6, &mut bus, &ipc);
        // 2 × 10 MB at 3,150 MB/s + setup
        let expected = 2.0 * 10e6 / 3.150e9 + bus.spec().setup_s;
        crate::util::testkit::assert_close(hop.duration_s, expected, 0.01, 0.0);
    }

    #[test]
    fn fig11_crossover_near_20kb() {
        // Paper: IPC wins above ~0.02 MB, loses for tiny payloads.
        let (bus, ipc) = setup();
        let (mm_tiny, ipc_tiny) = fig11_point(2.0, &bus, &ipc);
        assert!(mm_tiny < ipc_tiny, "tiny payloads favor main memory");
        let (mm_big, ipc_big) = fig11_point(0.05e6, &bus, &ipc);
        assert!(ipc_big < mm_big, "50 KB favors IPC");
        // locate the crossover: must sit between 2 B and 0.05 MB
        let mut lo = 2.0;
        let mut hi = 0.05e6;
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            let (mm, gi) = fig11_point(mid, &bus, &ipc);
            if mm < gi {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        assert!(lo > 1e3 && lo < 40e3, "crossover at {lo} bytes");
    }
}
