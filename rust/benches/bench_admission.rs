//! Online control-loop benchmarks: end-to-end admission trace replay
//! (admit / shrink / depart / re-pack, every interval validated in the
//! simulator) with the control-loop caches **cold** (disabled) vs
//! **warm** (memoized planner + deduplicated incremental replay), plus
//! the planner-memoization micro-benchmark.
//!
//! The trace deliberately repeats configurations (arrive/depart/arrive
//! cycles at fixed loads) because that is what real admission traffic
//! looks like — diurnal days revisit the same states — and it is
//! exactly what the `SolveCache` and interval dedup exploit. Cold and
//! warm runs produce bit-identical reports (`tests/control_loop_cache.rs`
//! pins this); only the wall clock differs.
//!
//! Results merge into `BENCH_sim.json` (run after `bench_sim`, which
//! rewrites the file): `derived.control_loop_speedup` is the headline
//! cold/warm ratio, `derived.solve_cache_hit_rate` the warm replay's
//! planner hit rate. `tools/bench_check` gates the replay benches with
//! a looser threshold than the sim benches (trace replay is noisier).
//!
//! Run with `cargo bench --bench bench_admission`.

use std::path::PathBuf;

use camelot::config::ClusterSpec;
use camelot::coordinator::admission::{replay_trace, ReplayConfig};
use camelot::coordinator::AdmissionConfig;
use camelot::planner::{
    CamelotPlanner, ClusterState, Objective, PlanRequest, Planner as _, SolveCache,
};
use camelot::predictor::train_pipeline;
use camelot::suite::real;
use camelot::suite::workload::TenantTrace;
use camelot::util::bench::{bench, header, JsonReport};

fn main() {
    let mut json = JsonReport::new();
    let cluster = ClusterSpec::two_2080ti();
    // the golden-gated repeated-configuration trace (same fixture the
    // control-loop golden suite replays)
    let trace = TenantTrace::repeated_cycle();
    let events = trace.events.len() as f64;

    header("online control loop (admission trace replay, cold vs warm)");
    let cold_cfg = ReplayConfig {
        queries: 300,
        dedup: false,
        admission: AdmissionConfig { solve_cache: 0, ..Default::default() },
        ..Default::default()
    };
    let warm_cfg = ReplayConfig { queries: 300, ..Default::default() };

    let cold = bench("admission/trace replay cold (no cache)", 5, || {
        replay_trace(&cluster, &trace, &cold_cfg).unwrap().admitted
    });
    json.add_with(&cold, &[("replay_events_per_s", events / cold.median_s)]);
    let warm = bench("admission/trace replay warm (memoized)", 5, || {
        replay_trace(&cluster, &trace, &warm_cfg).unwrap().admitted
    });
    json.add_with(&warm, &[("replay_events_per_s", events / warm.median_s)]);
    let speedup = cold.median_s / warm.median_s;
    println!("    -> control-loop speedup (cold/warm): {speedup:.2}x");
    json.derived("control_loop_speedup", speedup);

    // observability numbers from one warm replay: planner hit rate and
    // how many interval sims dedup absorbed
    let report = replay_trace(&cluster, &trace, &warm_cfg).unwrap();
    let hit_rate = report.solve_cache.hit_rate();
    println!(
        "    -> warm replay: solve-cache {}/{} hits ({:.0}%), intervals simulated {}/{}",
        report.solve_cache.hits,
        report.solve_cache.hits + report.solve_cache.misses,
        hit_rate * 100.0,
        report.intervals_simulated,
        report.intervals.len()
    );
    json.derived("solve_cache_hit_rate", hit_rate);
    json.derived(
        "replay_interval_dedup_frac",
        1.0 - report.intervals_simulated as f64 / report.intervals.len().max(1) as f64,
    );

    header("planner memoization (single Case-2 solve)");
    let p = real::img_to_text();
    let preds = train_pipeline(&p, &cluster.gpu);
    let req = PlanRequest::new(
        Objective::MinResource { load_qps: 80.0 },
        ClusterState::exclusive(&cluster),
        &p,
        &preds,
    );
    let uncached = bench("admission/solve min-resource (uncached)", 20, || {
        CamelotPlanner.plan(&req).is_ok()
    });
    json.add_with(&uncached, &[("solves_per_s", 1.0 / uncached.median_s)]);
    let cache = SolveCache::new(64);
    let _ = cache.plan(&req); // install the entry
    let hit = bench("admission/solve min-resource (cache hit)", 20, || {
        cache.plan(&req).is_ok()
    });
    // `cache_hits_per_s` is deliberately NOT a gated metric: a hit is a
    // key build + map lookup (microseconds), far too noisy to gate on a
    // shared runner — informational only
    json.add_with(&hit, &[("cache_hits_per_s", 1.0 / hit.median_s)]);
    let solve_speedup = uncached.median_s / hit.median_s;
    println!("    -> solve-cache hit speedup: {solve_speedup:.2}x");
    json.derived("solve_cache_speedup", solve_speedup);

    // merge into the file bench_sim wrote (repo root = parent of the
    // cargo package dir); entries this binary does not produce survive
    let out = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sim.json");
    let note = format!(
        "generated by `cargo bench --bench bench_sim` + `--bench bench_admission` with {} worker threads",
        camelot::util::par::max_threads()
    );
    match json.merge_write(&out, &note) {
        Ok(()) => println!("\nmerged into {}", out.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }
}
