//! §VIII-G overheads — the paper's three runtime-overhead claims:
//!   * online prediction completes in < 1 ms (DT; RF > 5 ms rejected),
//!   * the SA allocation solve completes in ~5 ms,
//!   * IPC channel setup ~1 ms, per-message overhead tiny.
//!
//! Run with `cargo bench --bench bench_overheads`.

use camelot::allocator::{max_load, AllocContext, SaParams};
use camelot::comm::{fig11_point, hop_cost, CommMode};
use camelot::config::{ClusterSpec, GpuSpec, IpcSpec, PcieSpec};
use camelot::predictor::{
    profile_stage, DecisionTree, ForestParams, LinReg, ProfileConfig, RandomForest,
    StagePredictor, TreeParams,
};
use camelot::sim::PcieBus;
use camelot::suite::real;
use camelot::util::bench::{bench, header};

fn main() {
    header("predictor inference (paper: DT < 1 ms, RF > 5 ms)");
    let gpu = GpuSpec::rtx2080ti();
    let stage = real::img_to_text().stages[0].clone();
    let samples = profile_stage(&stage, &gpu, &ProfileConfig::default());
    let xs: Vec<Vec<f64>> = samples.iter().map(|s| vec![s.batch, s.sm_frac]).collect();
    let ys: Vec<f64> = samples.iter().map(|s| s.duration_s).collect();
    let lr = LinReg::fit(&xs, &ys).unwrap();
    let dt = DecisionTree::fit(&xs, &ys, TreeParams::default());
    let rf = RandomForest::fit(&xs, &ys, ForestParams { n_trees: 400, ..Default::default() }, 3);
    let x = [32.0, 0.5];
    bench("predict/LR (single)", 20_000, || lr.predict(&x));
    bench("predict/DT (single)", 20_000, || dt.predict(&x));
    bench("predict/RF-400 (single)", 2_000, || rf.predict(&x));
    // "one prediction" in the paper = all stages × all quota candidates:
    let preds: Vec<StagePredictor> = real::img_to_text()
        .stages
        .iter()
        .map(|s| StagePredictor::train(s, &gpu, &ProfileConfig::default()))
        .collect();
    bench("predict/DT full-pipeline sweep (40 pts)", 2_000, || {
        let mut acc = 0.0;
        for p in &preds {
            for q in 1..=20 {
                acc += p.duration(32, q as f64 / 20.0);
            }
        }
        acc
    });

    header("allocation solve (paper: ~5 ms)");
    let cluster = ClusterSpec::two_2080ti();
    let pipeline = real::img_to_text();
    let ctx = AllocContext::new(&pipeline, &cluster, &preds, 32);
    for iters in [200usize, 1_000, 4_000] {
        let params = SaParams { iterations: iters, ..Default::default() };
        bench(&format!("sa/max-load {iters} iters"), 10, || {
            max_load::solve(&ctx, params)
        });
    }

    header("communication setup + per-message overheads");
    let ipc = IpcSpec::default();
    bench("comm/ipc hop_cost (same gpu)", 100_000, || {
        let mut bus = PcieBus::new(PcieSpec::default());
        hop_cost(CommMode::GlobalIpc, true, 1e6, &mut bus, &ipc)
    });
    bench("comm/fig11 analytic point", 100_000, || {
        let bus = PcieBus::new(PcieSpec::default());
        fig11_point(1e6, &bus, &ipc)
    });
    println!("\n(model constants: IPC setup {:.1} ms once per channel, {:.0} µs/msg)",
        ipc.setup_s * 1e3, ipc.per_msg_s * 1e6);
}
