//! PJRT request-path benchmarks: per-batch execution latency of the AOT
//! stage artifacts (the L3 hot path of the real serving deployment) and
//! the end-to-end coordinator round trip over the PJRT backend.
//!
//! Requires `make artifacts`. Run with `cargo bench --bench bench_runtime`.

use std::sync::Arc;
use std::time::Duration;

use camelot::coordinator::{Coordinator, CoordinatorConfig, ExecBackend, PjrtBackend};
use camelot::runtime::Engine;
use camelot::util::bench::{bench, header};

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping bench_runtime: run `make artifacts` first");
        return Ok(());
    }

    header("PJRT stage execution (per batch)");
    let mut engine = Engine::open("artifacts")?;
    for (stage, batch) in [
        ("vgg_features", 8u32),
        ("vgg_features", 64),
        ("lstm_caption", 8),
        ("bert_summarize", 32),
        ("artifact_memory", 32),
    ] {
        let exe = engine.load_stage(stage, batch)?;
        let n_in: usize = exe.meta.input_shape.iter().product();
        let input: Vec<f32> = (0..n_in).map(|i| (i % 17) as f32 * 0.02).collect();
        let r = bench(&format!("pjrt/{stage}_b{batch}"), 30, || exe.run(&input).unwrap());
        let gflops = exe.meta.flops / r.median_s / 1e9;
        println!("    -> {gflops:.1} GFLOP/s effective");
    }

    header("coordinator + PJRT end-to-end (batch 8, 2 stages)");
    let stages = vec!["vgg_features".to_string(), "lstm_caption".to_string()];
    let backend = Arc::new(PjrtBackend::new("artifacts", &stages, 8)?);
    {
        let row = vec![0.1f32; 512];
        let rows: Vec<&[f32]> = vec![row.as_slice(); 8];
        bench("pjrt-backend/stage0 full batch", 30, || {
            backend.execute(0, &rows).unwrap()
        });
    }
    let coord = Coordinator::launch(
        CoordinatorConfig {
            stages,
            instances: vec![1, 1],
            batch: 8,
            max_wait: Duration::from_millis(2),
        },
        backend,
    );
    bench("coordinator+pjrt/8-query batch roundtrip", 20, || {
        for _ in 0..8 {
            coord.submit(vec![0.1; 512]);
        }
        for _ in 0..8 {
            coord.recv_timeout(Duration::from_secs(30)).unwrap();
        }
    });
    coord.shutdown();
    Ok(())
}
