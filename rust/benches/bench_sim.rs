//! Simulator and coordinator hot-path benchmarks: event-engine
//! throughput (the figure sweeps run thousands of these simulations)
//! and the coordinator control-plane round trip.
//!
//! Run with `cargo bench --bench bench_sim`.

use std::sync::Arc;
use std::time::Duration;

use camelot::comm::CommMode;
use camelot::config::ClusterSpec;
use camelot::coordinator::{Coordinator, CoordinatorConfig, MockBackend};
use camelot::sim::{Deployment, InstancePlacement, SimOptions, Simulator};
use camelot::suite::real;
use camelot::util::bench::{bench, header};

fn main() {
    header("discrete-event engine");
    let p = real::img_to_text();
    let c = ClusterSpec::two_2080ti();
    let d = Deployment {
        placements: vec![
            InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
            InstancePlacement { stage: 0, gpu: 1, sm_frac: 0.5 },
            InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.4 },
            InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.4 },
        ],
        batch: 16,
        comm: CommMode::GlobalIpc,
    };
    for queries in [1_000usize, 4_000, 16_000] {
        let opts = SimOptions { queries, ..Default::default() };
        let sim = Simulator::new(&p, &c, &d, opts);
        let r = bench(&format!("sim/{queries} queries @300qps"), 10, || {
            sim.run(300.0).unwrap().completed
        });
        let qps = queries as f64 / r.median_s;
        println!("    -> {qps:.0} simulated queries/s of wall time");
    }

    header("coordinator control plane (mock backend)");
    for instances in [1usize, 2, 4] {
        let backend = Arc::new(MockBackend::identity(2));
        let coord = Coordinator::launch(
            CoordinatorConfig {
                stages: vec!["a".into(), "b".into()],
                instances: vec![instances; 2],
                batch: 8,
                max_wait: Duration::from_micros(200),
            },
            backend,
        );
        bench(&format!("coordinator/roundtrip x64 ({instances} inst/stage)"), 50, || {
            for _ in 0..64 {
                coord.submit(vec![1.0; 16]);
            }
            for _ in 0..64 {
                coord.recv_timeout(Duration::from_secs(5)).unwrap();
            }
        });
        coord.shutdown();
    }
}
