//! Simulator and coordinator hot-path benchmarks: event-engine
//! throughput (the figure sweeps run thousands of these simulations),
//! the peak-load search protocol, and the coordinator control-plane
//! round trip.
//!
//! Emits `BENCH_sim.json` at the repo root — {bench → median_s,
//! simulated-queries/s} plus optimized-vs-reference speedups — so
//! successive PRs accumulate a perf trajectory (EXPERIMENTS.md
//! §Benchmarks).
//!
//! Run with `cargo bench --bench bench_sim`. Set `CAMELOT_BENCH_FIGS=1`
//! to also time a full `fig17()` sweep (minutes, not seconds). The
//! optimized-vs-reference speedup sections need the seed engine:
//! `cargo bench --bench bench_sim --features reference-engine`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use camelot::comm::CommMode;
use camelot::config::ClusterSpec;
use camelot::coordinator::{Coordinator, CoordinatorConfig, MockBackend};
use camelot::figures::common;
use camelot::sim::{Deployment, InstancePlacement, SimOptions, Simulator};
use camelot::suite::real;
#[cfg(feature = "reference-engine")]
use camelot::suite::workload;
use camelot::util::bench::{bench, header, JsonReport};

fn main() {
    let mut json = JsonReport::new();

    header("discrete-event engine (optimized vs reference)");
    let p = real::img_to_text();
    let c = ClusterSpec::two_2080ti();
    let d = Deployment {
        placements: vec![
            InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
            InstancePlacement { stage: 0, gpu: 1, sm_frac: 0.5 },
            InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.4 },
            InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.4 },
        ],
        batch: 16,
        comm: CommMode::GlobalIpc,
    };
    for queries in [1_000usize, 4_000, 16_000] {
        let opts = SimOptions { queries, ..Default::default() };
        let sim = Simulator::new(&p, &c, &d, opts);
        let opt = bench(&format!("sim/{queries} queries @300qps"), 10, || {
            sim.run(300.0).unwrap().completed
        });
        let qps = queries as f64 / opt.median_s;
        println!("    -> {qps:.0} simulated queries/s of wall time");
        json.add_with(&opt, &[("sim_queries_per_s", qps)]);
        #[cfg(feature = "reference-engine")]
        {
            let refr =
                bench(&format!("sim/{queries} queries @300qps (reference)"), 10, || {
                    sim.run_reference(300.0).unwrap().completed
                });
            json.add_with(&refr, &[("sim_queries_per_s", queries as f64 / refr.median_s)]);
            let speedup = refr.median_s / opt.median_s;
            println!("    -> optimized engine speedup: {speedup:.2}x");
            json.derived(&format!("engine_speedup_{queries}q"), speedup);
        }
    }

    header("peak-load search protocol (coarse-to-fine vs serial seed)");
    {
        let opts = common::sweep_opts();
        let new_proto = bench("peak/coarse-to-fine + parallel probes", 3, || {
            common::peak_load(&p, &c, &d, &opts).0
        });
        json.add(&new_proto);
        #[cfg(feature = "reference-engine")]
        {
            let sim = Simulator::new(&p, &c, &d, opts.clone());
            let old_proto = bench("peak/serial seed protocol (reference engine)", 3, || {
                let (peak, _) = workload::peak_load_search(
                    |rate| {
                        sim.run_reference(rate).map(|r| r.p99()).unwrap_or(f64::INFINITY)
                    },
                    p.qos_target_s,
                    50.0,
                    0.03,
                );
                // the seed protocol re-ran the final rate for the report
                sim.run_reference(peak.max(1.0)).unwrap();
                peak
            });
            json.add(&old_proto);
            let speedup = old_proto.median_s / new_proto.median_s;
            println!("    -> peak-search speedup: {speedup:.2}x");
            json.derived("peak_search_speedup", speedup);
        }
    }

    if std::env::var("CAMELOT_BENCH_FIGS").is_ok() {
        header("full figure sweep (fig17, parallel cells)");
        let t0 = Instant::now();
        let tables = camelot::figures::macro_evals::fig17();
        let wall = t0.elapsed().as_secs_f64();
        println!("fig17 sweep: {wall:.1} s wall ({} rows)", tables[0].rows.len());
        json.derived("fig17_wall_s", wall);
    }

    header("coordinator control plane (mock backend)");
    for instances in [1usize, 2, 4] {
        let backend = Arc::new(MockBackend::identity(2));
        let coord = Coordinator::launch(
            CoordinatorConfig {
                stages: vec!["a".into(), "b".into()],
                instances: vec![instances; 2],
                batch: 8,
                max_wait: Duration::from_micros(200),
            },
            backend,
        );
        let r = bench(&format!("coordinator/roundtrip x64 ({instances} inst/stage)"), 50, || {
            for _ in 0..64 {
                coord.submit(vec![1.0; 16]);
            }
            for _ in 0..64 {
                coord.recv_timeout(Duration::from_secs(5)).unwrap();
            }
        });
        json.add(&r);
        coord.shutdown();
    }

    // repo root = parent of the cargo package dir
    let out = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sim.json");
    let note = format!(
        "generated by `cargo bench --bench bench_sim` with {} worker threads",
        camelot::util::par::max_threads()
    );
    match json.write(&out, &note) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }
}
