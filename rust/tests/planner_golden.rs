//! Golden suite for the unified planner API:
//!
//! * `Planner::plan` with `MaxLoad` / `MinResource` objectives returns
//!   **bit-identical** solutions to the legacy
//!   `allocator::{max_load, min_resource}::solve` entry points (the
//!   pre-refactor call shapes, now shims over the same engine) on the
//!   seed scenarios — exclusive and reservation-held clusters alike.
//!   Any drift between the two surfaces fails here.
//! * The planner's placement matches what the legacy callers built by
//!   hand (solve → bandwidth demands → deploy).
//! * Admission-trace replays that include the new `Shrink` events stay
//!   bit-identical across worker thread counts, and an applied shrink
//!   leaves a resident set the merged multi-tenant simulator admits.

use camelot::allocator::{max_load, min_resource, AllocContext, SaParams};
use camelot::comm::CommMode;
use camelot::config::ClusterSpec;
use camelot::coordinator::admission::{replay_trace, ReplayConfig};
use camelot::deploy::{self, GpuReservation};
use camelot::planner::{
    CamelotPlanner, ClusterState, Objective, PlanRequest, Planner as _,
};
use camelot::predictor::{train_pipeline, StagePredictor};
use camelot::sim::{ClusterSim, SimOptions, TenantSpec};
use camelot::suite::workload::{
    ArrivalProcess, Priority, TenantTrace, TenantTraceEvent, TraceEventKind,
};
use camelot::suite::Pipeline;

fn fixture(name: &str) -> (ClusterSpec, Pipeline, Vec<StagePredictor>) {
    let c = ClusterSpec::two_2080ti();
    let p = camelot::suite::pipeline_by_name(name).unwrap();
    let preds = train_pipeline(&p, &c.gpu);
    (c, p, preds)
}

/// The states every equivalence case runs under: exclusive, and with a
/// co-tenant holding part of each GPU.
fn states(c: &ClusterSpec) -> Vec<(&'static str, ClusterState)> {
    let held = vec![
        GpuReservation { sm_frac: 0.35, contexts: 4, mem_bytes: 1.5e9, bw_demand: 40.0e9 },
        GpuReservation { sm_frac: 0.10, contexts: 2, mem_bytes: 0.5e9, bw_demand: 10.0e9 },
    ];
    vec![
        ("exclusive", ClusterState::exclusive(c)),
        ("reserved", ClusterState::with_reservations(c, &held)),
    ]
}

/// Rebuild the deployment exactly the way the pre-refactor callers did:
/// solve, derive per-instance bandwidth demands, place with the 75%
/// bandwidth margin.
fn legacy_deploy(
    ctx: &AllocContext<'_>,
    state: &ClusterState,
    alloc: &camelot::deploy::Allocation,
    batch: u32,
) -> camelot::sim::Deployment {
    let demands = ctx.bw_budget_storage(alloc);
    deploy::deploy(
        ctx.pipeline,
        state,
        alloc,
        batch,
        CommMode::GlobalIpc,
        demands.as_deref().map(|d| deploy::BwBudget {
            demands: d,
            cap: 0.75 * state.spec().gpu.mem_bw,
        }),
    )
    .expect("legacy placement succeeds for a feasible allocation")
}

#[test]
fn max_load_plan_matches_legacy_solve_bit_for_bit() {
    for bench in ["img-to-text", "text-to-text"] {
        let (c, p, preds) = fixture(bench);
        for (tag, state) in states(&c) {
            let legacy_ctx = AllocContext::shared(&p, state.clone(), &preds, 16);
            let legacy = max_load::solve(&legacy_ctx, SaParams::default())
                .unwrap_or_else(|| panic!("{bench}/{tag}: legacy solves"));
            let req = PlanRequest::new(Objective::MaxLoad, state.clone(), &p, &preds).batch(16);
            let s = CamelotPlanner
                .plan(&req)
                .unwrap_or_else(|e| panic!("{bench}/{tag}: planner solves: {e}"));
            assert_eq!(s.allocation, legacy.best, "{bench}/{tag}: allocation drift");
            assert_eq!(
                s.objective_value.to_bits(),
                legacy.best_objective.to_bits(),
                "{bench}/{tag}: objective drift"
            );
            assert_eq!(
                (s.evaluated, s.feasible_found),
                (legacy.evaluated, legacy.feasible_found),
                "{bench}/{tag}: search-statistics drift"
            );
            let d = legacy_deploy(&legacy_ctx, &state, &legacy.best, 16);
            assert_eq!(
                s.deployment.placements, d.placements,
                "{bench}/{tag}: placement drift"
            );
        }
    }
}

#[test]
fn min_resource_plan_matches_legacy_solve_bit_for_bit() {
    for (bench, load) in [("text-to-text", 50.0), ("img-to-text", 90.0)] {
        let (c, p, preds) = fixture(bench);
        for (tag, state) in states(&c) {
            let legacy_ctx = AllocContext::shared(&p, state.clone(), &preds, 16);
            let legacy = min_resource::solve(&legacy_ctx, load, SaParams::default());
            let req = PlanRequest::new(
                Objective::MinResource { load_qps: load },
                state.clone(),
                &p,
                &preds,
            )
            .batch(16);
            let planned = CamelotPlanner.plan(&req);
            match (legacy, planned) {
                (Some((r, y)), Ok(s)) => {
                    assert_eq!(s.allocation, r.best, "{bench}/{tag}: allocation drift");
                    assert_eq!(
                        s.objective_value.to_bits(),
                        r.best_objective.to_bits(),
                        "{bench}/{tag}: objective drift"
                    );
                    let d = legacy_deploy(&legacy_ctx, &state, &r.best, 16);
                    assert_eq!(
                        s.deployment.placements, d.placements,
                        "{bench}/{tag}: placement drift"
                    );
                    // gpus counts what the placement occupies (the Eq. 2
                    // sub-cluster size y only proves prefix feasibility)
                    assert_eq!(
                        s.gpus,
                        deploy::gpus_in_use([&d]),
                        "{bench}/{tag}: occupied-GPU drift (solver y={y})"
                    );
                }
                (None, Err(_)) => {}
                (l, pl) => panic!(
                    "{bench}/{tag}: feasibility disagrees: legacy={:?} planner={:?}",
                    l.map(|(r, y)| (r.best, y)),
                    pl.map(|s| (s.allocation, s.gpus))
                ),
            }
        }
    }
}

/// A hand-built trace exercising arrive, shrink, and depart.
fn shrink_trace() -> TenantTrace {
    let mk = |t_s: f64, tenant: u64, kind: TraceEventKind| TenantTraceEvent { t_s, tenant, kind };
    TenantTrace {
        events: vec![
            mk(
                0.0,
                0,
                TraceEventKind::Arrive {
                    pipeline: "img-to-text".into(),
                    name: None,
                    arrivals: ArrivalProcess::constant(120.0),
                    plan_qps: 120.0,
                    priority: Priority::LatencyCritical,
                },
            ),
            mk(
                50.0,
                1,
                TraceEventKind::Arrive {
                    pipeline: "text-to-text".into(),
                    name: None,
                    arrivals: ArrivalProcess::constant(70.0),
                    plan_qps: 70.0,
                    priority: Priority::LatencyCritical,
                },
            ),
            mk(100.0, 0, TraceEventKind::Shrink { target_qps: 35.0 }),
            mk(200.0, 1, TraceEventKind::Depart),
            // shrinking a tenant that never admitted is a logged no-op
            mk(250.0, 9, TraceEventKind::Shrink { target_qps: 10.0 }),
        ],
    }
}

#[test]
fn shrink_trace_replay_is_thread_count_invariant() {
    let cluster = ClusterSpec::two_2080ti();
    let trace = shrink_trace();
    let fingerprint = |threads: usize| -> Vec<String> {
        let cfg = ReplayConfig { queries: 300, threads, ..Default::default() };
        let rep = replay_trace(&cluster, &trace, &cfg).expect("replay runs");
        let mut out: Vec<String> = rep
            .events
            .iter()
            .map(|e| {
                format!("{} {} -> {} usage={}", e.tenant, e.desc, e.decision, e.usage.to_bits())
            })
            .collect();
        for iv in &rep.intervals {
            out.push(format!(
                "iv {} {:?}",
                iv.t_start_s.to_bits(),
                iv.p99_s.iter().map(|p| p.to_bits()).collect::<Vec<_>>()
            ));
        }
        out
    };
    let serial = fingerprint(1);
    // the trace must actually exercise the shrink path
    assert!(
        serial.iter().any(|l| l.contains("shrink") && l.contains("applied")),
        "expected an applied shrink in {serial:?}"
    );
    assert!(serial.iter().any(|l| l.contains("no-op")));
    for threads in [2usize, 8] {
        assert_eq!(serial, fingerprint(threads), "replay differs at {threads} threads");
    }
}

#[test]
fn applied_shrink_leaves_an_admissible_resident_set() {
    use camelot::coordinator::{AdmissionConfig, AdmissionController};
    let cluster = ClusterSpec::two_2080ti();
    let mut ctl = AdmissionController::new(cluster.clone(), AdmissionConfig::default());
    let p1 = camelot::suite::pipeline_by_name("img-to-text").unwrap();
    let p2 = camelot::suite::pipeline_by_name("text-to-text").unwrap();
    let id = ctl
        .try_admit("a", &p1, ArrivalProcess::constant(120.0), 120.0)
        .expect("a admits");
    ctl.try_admit("b", &p2, ArrivalProcess::constant(70.0), 70.0)
        .expect("b admits");
    let rep = ctl.shrink_resident(id, 35.0).expect("a shrinks");
    assert!(rep.applied, "{}", rep.summary());
    // the post-shrink resident set must co-exist on the shared GPUs:
    // the merged multi-tenant engine's admission check is the arbiter
    let specs: Vec<TenantSpec> = ctl
        .residents()
        .iter()
        .map(|r| TenantSpec {
            pipeline: &r.pipeline,
            deployment: &r.deployment,
            arrivals: r.arrivals.clone(),
        })
        .collect();
    ClusterSim::new(&cluster, specs, SimOptions { queries: 64, ..Default::default() })
        .admit()
        .expect("shrunken resident set co-exists");
}
