//! Runtime + coordinator end-to-end integration over the real AOT
//! artifacts (requires `make artifacts`; tests skip gracefully without).
//!
//! This is the seam where all three layers compose: Pallas kernels (L1)
//! inside JAX stage graphs (L2), served through PJRT by the Rust
//! coordinator (L3) with Python nowhere at runtime.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use camelot::coordinator::{Coordinator, CoordinatorConfig, ExecBackend, PjrtBackend};
use camelot::runtime::Engine;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn manifest_lists_all_default_variants() {
    let Some(dir) = artifacts() else { return };
    let e = Engine::open(dir).unwrap();
    // 10 stages × 4 batch sizes from python/compile/model.py
    assert_eq!(e.manifest().len(), 40);
    for m in e.manifest().iter() {
        assert!(m.flops > 0.0, "{}: flops", m.name);
        assert_eq!(m.input_shape.len(), 2);
        assert_eq!(m.input_shape[0] as u32, m.batch);
    }
}

#[test]
fn every_pipeline_pair_composes_through_pjrt() {
    // chain both stages of each real pipeline at batch 8; the output of
    // stage 1 must be a valid input for stage 2
    let Some(dir) = artifacts() else { return };
    let mut e = Engine::open(dir).unwrap();
    let pipelines = [
        ("face_recognition", "fsrcnn_enhance"),
        ("vgg_features", "lstm_caption"),
        ("lstm_semantic", "dcgan_generate"),
        ("bert_summarize", "nmt_translate"),
    ];
    for (s1, s2) in pipelines {
        let n_in: usize = e.load_stage(s1, 8).unwrap().meta.input_shape.iter().product();
        let input: Vec<f32> = (0..n_in).map(|i| ((i % 29) as f32 - 14.0) * 0.01).collect();
        let mid = e.load_stage(s1, 8).unwrap().run(&input).unwrap();
        let out = e.load_stage(s2, 8).unwrap().run(&mid).unwrap();
        assert!(out.iter().all(|x| x.is_finite()), "{s1}->{s2}");
        let expected: usize =
            e.load_stage(s2, 8).unwrap().meta.output_shape.iter().product();
        assert_eq!(out.len(), expected, "{s1}->{s2}");
    }
}

#[test]
fn pjrt_backend_batch_padding_is_invisible() {
    // a 3-row batch through a batch-8 artifact must equal the same rows
    // in a full batch (zero-padding must not leak into real rows)
    let Some(dir) = artifacts() else { return };
    let stages = vec!["fsrcnn_enhance".to_string()];
    let b = PjrtBackend::new(dir, &stages, 8).unwrap();
    let rows: Vec<Vec<f32>> = (0..8)
        .map(|r| (0..256).map(|i| ((i + r * 7) % 11) as f32 * 0.1).collect())
        .collect();
    let all: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let full = b.execute(0, &all).unwrap();
    let partial = b.execute(0, &all[..3]).unwrap();
    for i in 0..3 {
        assert_eq!(full[i], partial[i], "row {i} differs under padding");
    }
}

#[test]
fn coordinator_serves_real_pipeline_under_load() {
    // the E2E serving path: Poisson-less burst of 48 queries through
    // the 2-stage img-to-text proxy, all complete within a wall-clock
    // budget and with finite outputs
    let Some(dir) = artifacts() else { return };
    let stages = vec!["vgg_features".to_string(), "lstm_caption".to_string()];
    let backend = Arc::new(PjrtBackend::new(dir, &stages, 8).unwrap());
    let c = Coordinator::launch(
        CoordinatorConfig {
            stages,
            instances: vec![2, 2],
            batch: 8,
            max_wait: Duration::from_millis(10),
        },
        backend,
    );
    for _ in 0..48 {
        c.submit(vec![0.25; 512]);
    }
    for _ in 0..48 {
        let comp = c.recv_timeout(Duration::from_secs(60)).expect("completion");
        assert_eq!(comp.output.len(), 512);
        assert!(comp.output.iter().all(|x| x.is_finite()));
    }
    let hist = c.histogram();
    assert_eq!(hist.count(), 48);
    assert!(hist.p99() > 0.0);
    c.shutdown();
}
