//! Golden suite for the memoized control loop (PR 5):
//!
//! * [`SolveCache`]-served plans are **bit-identical** to direct
//!   `CamelotPlanner::plan` solves — exclusive and reservation-held
//!   clusters, Case-1 and Case-2 objectives alike;
//! * `replay_trace` with memoization + interval dedup enabled produces
//!   a report bit-identical to the fully uncached path, across 1/2/8
//!   worker threads, on both a generated admission trace and a crafted
//!   repeated-configuration trace (where the caches demonstrably fire);
//! * the degenerate single-tenant constant-rate interval fast path
//!   (optimized `Simulator::run`) matches the merged `ClusterSim`
//!   bit-for-bit, closing the equivalence chain the fast path rests on;
//! * the LRU stays within its configured capacity on long request
//!   streams (no unbounded memory on week-long traces).

use camelot::config::ClusterSpec;
use camelot::coordinator::admission::{replay_trace, AdmissionController, ReplayConfig};
use camelot::coordinator::AdmissionConfig;
use camelot::deploy::GpuReservation;
use camelot::planner::{
    CamelotPlanner, ClusterState, Objective, PlanRequest, Planner as _, SolveCache, Solution,
};
use camelot::predictor::train_pipeline;
use camelot::sim::{ClusterSim, SimOptions, TenantSpec};
use camelot::suite::workload::{
    ArrivalProcess, Priority, TenantTrace, TenantTraceConfig, TenantTraceEvent, TraceEventKind,
};

fn assert_bit_identical(tag: &str, a: &Solution, b: &Solution) {
    assert_eq!(a.allocation, b.allocation, "{tag}: allocation drift");
    assert_eq!(
        a.deployment.placements, b.deployment.placements,
        "{tag}: placement drift"
    );
    assert_eq!(a.plan_qps.to_bits(), b.plan_qps.to_bits(), "{tag}: plan_qps drift");
    assert_eq!(
        a.predicted_p99_s.to_bits(),
        b.predicted_p99_s.to_bits(),
        "{tag}: p99 drift"
    );
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&a.stage_p99_s), bits(&b.stage_p99_s), "{tag}: stage p99 drift");
    assert_eq!(a.usage.to_bits(), b.usage.to_bits(), "{tag}: usage drift");
    assert_eq!(a.gpus, b.gpus, "{tag}: gpu-count drift");
    assert_eq!(
        a.objective_value.to_bits(),
        b.objective_value.to_bits(),
        "{tag}: objective drift"
    );
    assert_eq!(
        (a.evaluated, a.feasible_found),
        (b.evaluated, b.feasible_found),
        "{tag}: search-statistics drift"
    );
}

#[test]
fn memoized_plans_are_bit_identical_to_direct_solves() {
    let c = ClusterSpec::two_2080ti();
    // the same held-cluster shape the planner golden suite uses
    let held = vec![
        GpuReservation { sm_frac: 0.35, contexts: 4, mem_bytes: 1.5e9, bw_demand: 40.0e9 },
        GpuReservation { sm_frac: 0.10, contexts: 2, mem_bytes: 0.5e9, bw_demand: 10.0e9 },
    ];
    for bench in ["img-to-text", "text-to-text"] {
        let p = camelot::suite::pipeline_by_name(bench).unwrap();
        let preds = train_pipeline(&p, &c.gpu);
        let cache = SolveCache::new(64);
        let mut planned = 0u64;
        for (tag, state) in [
            ("exclusive", ClusterState::exclusive(&c)),
            ("reserved", ClusterState::with_reservations(&c, &held)),
        ] {
            for objective in [
                Objective::MaxLoad,
                Objective::MinResource { load_qps: 60.0 },
            ] {
                let label = format!("{bench}/{tag}/{}", objective.name());
                let req =
                    PlanRequest::new(objective, state.clone(), &p, &preds).batch(16);
                let direct = CamelotPlanner
                    .plan(&req)
                    .unwrap_or_else(|e| panic!("{label}: direct solve fails: {e}"));
                let miss = cache.plan(&req).expect("cached miss solves");
                let hit = cache.plan(&req).expect("cached hit solves");
                assert_bit_identical(&format!("{label} (miss)"), &direct, &miss);
                assert_bit_identical(&format!("{label} (hit)"), &direct, &hit);
                planned += 1;
            }
        }
        let stats = cache.stats();
        assert_eq!(stats.misses, planned, "{bench}: one miss per distinct request");
        assert_eq!(stats.hits, planned, "{bench}: one hit per repeat");
        assert_eq!(stats.evictions, 0);
    }
}

// Reports are compared through `ReplayReport::fingerprint()` — every
// decision and measurement flattened to exact bits (cache counters and
// dedup bookkeeping deliberately excluded: they differ between the
// cached and uncached paths by design).

fn cached_cfg(queries: usize, threads: usize) -> ReplayConfig {
    ReplayConfig { queries, threads, ..Default::default() }
}

fn uncached_cfg(queries: usize, threads: usize) -> ReplayConfig {
    ReplayConfig {
        queries,
        threads,
        dedup: false,
        admission: AdmissionConfig { solve_cache: 0, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn cached_replay_is_bit_identical_to_uncached_across_threads() {
    let cluster = ClusterSpec::two_2080ti();
    // a generated trace (diurnal arrivals, organic churn) and the
    // crafted repeated-configuration trace both must agree exactly
    let generated = TenantTrace::generate(
        &TenantTraceConfig {
            tenants: 5,
            mean_interarrival_s: 300.0,
            mean_lifetime_s: 900.0,
            peak_qps_lo: 40.0,
            peak_qps_hi: 110.0,
            ..Default::default()
        },
        2024,
    );
    for (tag, trace) in [
        ("generated", &generated),
        ("repeated", &TenantTrace::repeated_cycle()),
    ] {
        let baseline = replay_trace(&cluster, trace, &uncached_cfg(300, 1))
            .expect("uncached replay")
            .fingerprint();
        for threads in [1usize, 2, 8] {
            let uncached =
                replay_trace(&cluster, trace, &uncached_cfg(300, threads)).expect("replay");
            assert_eq!(uncached.solve_cache.hits, 0, "{tag}: disabled cache must not hit");
            assert_eq!(
                uncached.intervals_simulated,
                uncached.intervals.len(),
                "{tag}: dedup off simulates every interval"
            );
            assert_eq!(
                baseline,
                uncached.fingerprint(),
                "{tag}: uncached replay differs at {threads} threads"
            );
            let cached =
                replay_trace(&cluster, trace, &cached_cfg(300, threads)).expect("replay");
            assert_eq!(
                baseline,
                cached.fingerprint(),
                "{tag}: cached replay differs at {threads} threads"
            );
        }
    }
}

#[test]
fn repeated_trace_actually_exercises_the_caches() {
    // the equality test above would pass vacuously if nothing ever hit;
    // this pins that the repeated-configuration trace really does warm
    // both layers
    let cluster = ClusterSpec::two_2080ti();
    let trace = TenantTrace::repeated_cycle();
    let rep = replay_trace(&cluster, &trace, &cached_cfg(300, 1)).expect("replay");
    assert!(
        rep.solve_cache.hits > 0,
        "repeated admissions/re-packs must hit the solve cache: {:?}",
        rep.solve_cache
    );
    assert!(
        rep.intervals_simulated < rep.intervals.len(),
        "repeated resident sets must dedup intervals: {}/{}",
        rep.intervals_simulated,
        rep.intervals.len()
    );
    // and the cache stays bounded even at a tiny capacity, with the
    // decisions unchanged (evictions only cost re-solves)
    let mut tiny = cached_cfg(300, 1);
    tiny.admission.solve_cache = 2;
    let rep_tiny = replay_trace(&cluster, &trace, &tiny).expect("replay");
    assert!(rep_tiny.solve_cache.entries <= 2, "{:?}", rep_tiny.solve_cache);
    assert_eq!(rep.fingerprint(), rep_tiny.fingerprint());
}

#[test]
fn fast_path_interval_matches_cluster_sim_bit_for_bit() {
    // single-tenant constant-rate intervals route through the optimized
    // Simulator::run; the merged ClusterSim must agree exactly (the
    // degenerate-equivalence contract the fast path rests on)
    let cluster = ClusterSpec::two_2080ti();
    let rate = 90.0;
    let queries = 600;
    let trace = TenantTrace {
        events: vec![TenantTraceEvent {
            t_s: 0.0,
            tenant: 0,
            kind: TraceEventKind::Arrive {
                pipeline: "img-to-text".into(),
                name: None,
                arrivals: ArrivalProcess::constant(rate),
                plan_qps: rate,
                priority: Priority::LatencyCritical,
            },
        }],
    };
    let cfg = cached_cfg(queries, 1);
    let rep = replay_trace(&cluster, &trace, &cfg).expect("replay");
    assert_eq!(rep.intervals.len(), 1);
    assert_eq!(rep.intervals[0].p99_s.len(), 1);

    // recover the controller's deployment deterministically, then run
    // the merged multi-tenant engine on the same seed (interval 0 mixes
    // the base seed with index 0 = the base seed itself)
    let p = camelot::suite::pipeline_by_name("img-to-text").unwrap();
    let mut ctl = AdmissionController::new(cluster.clone(), cfg.admission.clone());
    ctl.try_admit("img-to-text#0", &p, ArrivalProcess::constant(rate), rate)
        .expect("admits");
    let d = ctl.residents()[0].deployment.clone();
    let merged = ClusterSim::new(
        &cluster,
        vec![TenantSpec {
            pipeline: &p,
            deployment: &d,
            arrivals: ArrivalProcess::constant(rate),
        }],
        SimOptions { seed: cfg.admission.seed, queries, ..Default::default() },
    )
    .run()
    .expect("merged sim runs");
    assert_eq!(
        rep.intervals[0].p99_s[0].to_bits(),
        merged[0].p99().to_bits(),
        "fast-path p99 {} vs ClusterSim {}",
        rep.intervals[0].p99_s[0],
        merged[0].p99()
    );
}
