//! Golden-equivalence and determinism tests for the optimized engine
//! and the parallel sweep executor (ISSUE 1 acceptance criteria):
//!
//! * `Simulator::run` (optimized) must reproduce the seed algorithm
//!   (`Simulator::run_reference`) exactly — same `p99`, `completed`,
//!   and time-breakdown totals for fixed seeds on every real pipeline;
//! * parallel sweeps must be bit-identical regardless of thread count.

use camelot::comm::CommMode;
use camelot::config::ClusterSpec;
use camelot::sim::{Deployment, InstancePlacement, SimOptions, Simulator};
use camelot::suite::{real, workload};
use camelot::util::par::par_map_threads;

fn colocated(batch: u32, comm: CommMode) -> Deployment {
    Deployment {
        placements: vec![
            InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
            InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.5 },
        ],
        batch,
        comm,
    }
}

fn spread(batch: u32, comm: CommMode) -> Deployment {
    Deployment {
        placements: vec![
            InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
            InstancePlacement { stage: 0, gpu: 1, sm_frac: 0.5 },
            InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.4 },
            InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.4 },
        ],
        batch,
        comm,
    }
}

fn assert_reports_identical(tag: &str, sim: &Simulator, rate: f64) {
    let opt = sim.run(rate).unwrap();
    let refr = sim.run_reference(rate).unwrap();
    assert_eq!(opt.completed, refr.completed, "{tag}: completed");
    assert_eq!(
        opt.p99().to_bits(),
        refr.p99().to_bits(),
        "{tag}: p99 {} vs {}",
        opt.p99(),
        refr.p99()
    );
    assert_eq!(
        opt.hist.count(),
        refr.hist.count(),
        "{tag}: histogram count"
    );
    assert_eq!(
        opt.hist.mean().to_bits(),
        refr.hist.mean().to_bits(),
        "{tag}: mean latency"
    );
    for (name, a, b) in [
        ("queue_s", opt.breakdown.queue_s, refr.breakdown.queue_s),
        ("exec_s", opt.breakdown.exec_s, refr.breakdown.exec_s),
        ("upload_s", opt.breakdown.upload_s, refr.breakdown.upload_s),
        ("hop_s", opt.breakdown.hop_s, refr.breakdown.hop_s),
        ("download_s", opt.breakdown.download_s, refr.breakdown.download_s),
    ] {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: breakdown {name}: {a} vs {b}");
    }
    assert_eq!(
        opt.achieved_qps.to_bits(),
        refr.achieved_qps.to_bits(),
        "{tag}: achieved_qps"
    );
    for (i, (a, b)) in opt
        .stage_exec_mean_s
        .iter()
        .zip(&refr.stage_exec_mean_s)
        .enumerate()
    {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: stage {i} exec mean");
    }
}

#[test]
fn optimized_engine_matches_reference_on_all_real_pipelines() {
    let cluster = ClusterSpec::two_2080ti();
    for p in real::all() {
        for (dname, d) in [
            ("colocated-ipc", colocated(16, CommMode::GlobalIpc)),
            ("colocated-mm", colocated(16, CommMode::MainMemory)),
            ("spread-ipc", spread(16, CommMode::GlobalIpc)),
            ("spread-mm", spread(16, CommMode::MainMemory)),
        ] {
            for seed in [42u64, 7] {
                let opts = SimOptions { seed, queries: 800, ..Default::default() };
                let sim = Simulator::new(&p, &cluster, &d, opts);
                if sim.admit().is_err() {
                    continue;
                }
                // light load, near saturation, and overload
                for rate in [30.0, 150.0, 900.0] {
                    assert_reports_identical(
                        &format!("{}/{dname}/seed{seed}@{rate}", p.name),
                        &sim,
                        rate,
                    );
                }
            }
        }
    }
}

#[test]
fn golden_equivalence_on_large_batches_and_dgx2() {
    // batch and cluster variation: the request-granular arithmetic must
    // agree everywhere, not just on the 2×2080Ti defaults
    let p = real::text_to_text();
    for (cluster, batch) in [
        (ClusterSpec::two_2080ti(), 64u32),
        (ClusterSpec::dgx2(), 32),
    ] {
        let d = spread(batch, CommMode::GlobalIpc);
        let opts = SimOptions { queries: 1_600, ..Default::default() };
        let sim = Simulator::new(&p, &cluster, &d, opts);
        if sim.admit().is_err() {
            continue;
        }
        for rate in [80.0, 400.0] {
            assert_reports_identical(&format!("{}@{rate}", cluster.gpu.name), &sim, rate);
        }
    }
}

#[test]
fn parallel_sim_sweep_identical_across_thread_counts() {
    let p = real::img_to_text();
    let cluster = ClusterSpec::two_2080ti();
    let d = spread(16, CommMode::GlobalIpc);
    let opts = SimOptions { queries: 600, ..Default::default() };
    let sim = Simulator::new(&p, &cluster, &d, opts);
    let rates: Vec<f64> = (1..=8).map(|i| 40.0 * i as f64).collect();
    let sweep = |threads: usize| {
        par_map_threads(&rates, threads, |_, &rate| {
            let rep = sim.run(rate).unwrap();
            (
                rep.completed,
                rep.p99().to_bits(),
                rep.breakdown.total().to_bits(),
            )
        })
    };
    let serial = sweep(1);
    for threads in [2, 4, 7] {
        assert_eq!(serial, sweep(threads), "sweep differs at {threads} threads");
    }
}

#[test]
fn speculative_peak_search_identical_across_thread_counts() {
    let p = real::img_to_text();
    let cluster = ClusterSpec::two_2080ti();
    let d = colocated(16, CommMode::GlobalIpc);
    let opts = SimOptions { queries: 600, ..Default::default() };
    let sim = Simulator::new(&p, &cluster, &d, opts);
    let search = |threads: usize| {
        workload::peak_load_search_bracketed(
            |rates| {
                par_map_threads(rates, threads, |_, &rate| {
                    sim.run(rate).map(|r| r.p99()).unwrap_or(f64::INFINITY)
                })
            },
            p.qos_target_s,
            50.0,
            2_000.0,
            0.03,
            3,
        )
    };
    let (peak1, trials1) = search(1);
    for threads in [3, 8] {
        let (peak_n, trials_n) = search(threads);
        assert_eq!(peak1.to_bits(), peak_n.to_bits(), "{threads} threads");
        assert_eq!(trials1.len(), trials_n.len());
        for (a, b) in trials1.iter().zip(&trials_n) {
            assert_eq!(a.rate_qps.to_bits(), b.rate_qps.to_bits());
            assert_eq!(a.p99_s.to_bits(), b.p99_s.to_bits());
            assert_eq!(a.qos_met, b.qos_met);
        }
    }
    assert!(peak1 > 0.0, "search must find a feasible load");
}
