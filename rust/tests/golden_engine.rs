//! Golden-equivalence, property, and determinism tests for the
//! simulation engines and the parallel sweep executor:
//!
//! * `Simulator::run` (optimized) must reproduce the seed algorithm
//!   (`Simulator::run_reference`) exactly — these oracle tests compile
//!   only under `--features reference-engine` (the CI golden leg), so
//!   ordinary builds don't carry the reference path;
//! * `ClusterSim` with one tenant and constant-rate arrivals must be
//!   bit-identical to `Simulator::run` (degenerate equivalence — always
//!   on, it needs no reference engine);
//! * non-homogeneous arrivals are reproducible per seed and monotone in
//!   rate scale under a shared dominating rate;
//! * single- and multi-tenant sweeps are bit-identical regardless of
//!   thread count.

use camelot::comm::CommMode;
use camelot::config::ClusterSpec;
use camelot::sim::{
    ClusterSim, Deployment, InstancePlacement, SimOptions, SimReport, Simulator, TenantSpec,
};
use camelot::suite::workload::{
    ArrivalProcess, DiurnalPattern, NonHomogeneousArrivals,
};
use camelot::suite::{real, workload};
use camelot::util::par::par_map_threads;

fn colocated(batch: u32, comm: CommMode) -> Deployment {
    Deployment {
        placements: vec![
            InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
            InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.5 },
        ],
        batch,
        comm,
    }
}

fn spread(batch: u32, comm: CommMode) -> Deployment {
    Deployment {
        placements: vec![
            InstancePlacement { stage: 0, gpu: 0, sm_frac: 0.5 },
            InstancePlacement { stage: 0, gpu: 1, sm_frac: 0.5 },
            InstancePlacement { stage: 1, gpu: 0, sm_frac: 0.4 },
            InstancePlacement { stage: 1, gpu: 1, sm_frac: 0.4 },
        ],
        batch,
        comm,
    }
}

/// Two half-cluster deployments that co-exist on the 2×2080Ti: each
/// tenant gets 45% + 35% of both GPUs.
fn half_cluster_pair(batch: u32) -> (Deployment, Deployment) {
    let mk = |q0: f64, q1: f64| Deployment {
        placements: vec![
            InstancePlacement { stage: 0, gpu: 0, sm_frac: q0 },
            InstancePlacement { stage: 1, gpu: 1, sm_frac: q1 },
        ],
        batch,
        comm: CommMode::GlobalIpc,
    };
    (mk(0.45, 0.35), mk(0.35, 0.45))
}

fn assert_same_report(tag: &str, a: &SimReport, b: &SimReport) {
    assert_eq!(a.completed, b.completed, "{tag}: completed");
    assert_eq!(
        a.p99().to_bits(),
        b.p99().to_bits(),
        "{tag}: p99 {} vs {}",
        a.p99(),
        b.p99()
    );
    assert_eq!(a.hist.count(), b.hist.count(), "{tag}: histogram count");
    assert_eq!(
        a.hist.mean().to_bits(),
        b.hist.mean().to_bits(),
        "{tag}: mean latency"
    );
    for (name, x, y) in [
        ("queue_s", a.breakdown.queue_s, b.breakdown.queue_s),
        ("exec_s", a.breakdown.exec_s, b.breakdown.exec_s),
        ("upload_s", a.breakdown.upload_s, b.breakdown.upload_s),
        ("hop_s", a.breakdown.hop_s, b.breakdown.hop_s),
        ("download_s", a.breakdown.download_s, b.breakdown.download_s),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: breakdown {name}: {x} vs {y}");
    }
    assert_eq!(
        a.achieved_qps.to_bits(),
        b.achieved_qps.to_bits(),
        "{tag}: achieved_qps"
    );
    for (i, (x, y)) in a
        .stage_exec_mean_s
        .iter()
        .zip(&b.stage_exec_mean_s)
        .enumerate()
    {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: stage {i} exec mean");
    }
}

// ---------------------------------------------------------------------
// Optimized engine vs the seed reference (needs `reference-engine`)
// ---------------------------------------------------------------------

#[cfg(feature = "reference-engine")]
fn assert_reports_identical(tag: &str, sim: &Simulator, rate: f64) {
    let opt = sim.run(rate).unwrap();
    let refr = sim.run_reference(rate).unwrap();
    assert_same_report(tag, &opt, &refr);
}

#[cfg(feature = "reference-engine")]
#[test]
fn optimized_engine_matches_reference_on_all_real_pipelines() {
    let cluster = ClusterSpec::two_2080ti();
    for p in real::all() {
        for (dname, d) in [
            ("colocated-ipc", colocated(16, CommMode::GlobalIpc)),
            ("colocated-mm", colocated(16, CommMode::MainMemory)),
            ("spread-ipc", spread(16, CommMode::GlobalIpc)),
            ("spread-mm", spread(16, CommMode::MainMemory)),
        ] {
            for seed in [42u64, 7] {
                let opts = SimOptions { seed, queries: 800, ..Default::default() };
                let sim = Simulator::new(&p, &cluster, &d, opts);
                if sim.admit().is_err() {
                    continue;
                }
                // light load, near saturation, and overload
                for rate in [30.0, 150.0, 900.0] {
                    assert_reports_identical(
                        &format!("{}/{dname}/seed{seed}@{rate}", p.name),
                        &sim,
                        rate,
                    );
                }
            }
        }
    }
}

#[cfg(feature = "reference-engine")]
#[test]
fn golden_equivalence_on_large_batches_and_dgx2() {
    // batch and cluster variation: the request-granular arithmetic must
    // agree everywhere, not just on the 2×2080Ti defaults
    let p = real::text_to_text();
    for (cluster, batch) in [
        (ClusterSpec::two_2080ti(), 64u32),
        (ClusterSpec::dgx2(), 32),
    ] {
        let d = spread(batch, CommMode::GlobalIpc);
        let opts = SimOptions { queries: 1_600, ..Default::default() };
        let sim = Simulator::new(&p, &cluster, &d, opts);
        if sim.admit().is_err() {
            continue;
        }
        for rate in [80.0, 400.0] {
            assert_reports_identical(&format!("{}@{rate}", cluster.gpu.name), &sim, rate);
        }
    }
}

// ---------------------------------------------------------------------
// Degenerate equivalence: ClusterSim(1 tenant, constant) == Simulator
// ---------------------------------------------------------------------

#[test]
fn cluster_sim_degenerates_to_single_engine_bit_identically() {
    let cluster = ClusterSpec::two_2080ti();
    for p in real::all() {
        for (dname, d) in [
            ("colocated-ipc", colocated(16, CommMode::GlobalIpc)),
            ("colocated-mm", colocated(16, CommMode::MainMemory)),
            ("spread-ipc", spread(16, CommMode::GlobalIpc)),
            ("spread-mm", spread(32, CommMode::MainMemory)),
        ] {
            for seed in [42u64, 7] {
                let opts = SimOptions { seed, queries: 800, ..Default::default() };
                let sim = Simulator::new(&p, &cluster, &d, opts.clone());
                if sim.admit().is_err() {
                    continue;
                }
                for rate in [30.0, 150.0, 900.0] {
                    let single = sim.run(rate).unwrap();
                    let multi = ClusterSim::new(
                        &cluster,
                        vec![TenantSpec {
                            pipeline: &p,
                            deployment: &d,
                            arrivals: ArrivalProcess::constant(rate),
                        }],
                        opts.clone(),
                    )
                    .run()
                    .unwrap();
                    assert_eq!(multi.len(), 1);
                    assert_same_report(
                        &format!("{}/{dname}/seed{seed}@{rate}", p.name),
                        &multi[0],
                        &single,
                    );
                    // offered_qps is the constant rate verbatim
                    assert_eq!(multi[0].offered_qps.to_bits(), single.offered_qps.to_bits());
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Non-homogeneous arrival properties
// ---------------------------------------------------------------------

#[test]
fn nonhomogeneous_arrivals_reproducible_per_seed() {
    for seed in [1u64, 42, 9_999] {
        let pattern = DiurnalPattern::new(250.0);
        let a = NonHomogeneousArrivals::new(pattern.clone(), seed).take_times(2_000);
        let b = NonHomogeneousArrivals::new(pattern, seed).take_times(2_000);
        assert_eq!(a, b, "seed {seed} must replay bit-identically");
    }
}

#[test]
fn nonhomogeneous_arrivals_monotone_in_rate_scale() {
    // under a shared dominating rate, a pointwise-larger pattern accepts
    // a superset of the candidate arrivals — so every prefix horizon
    // contains at least as many arrivals, per seed, deterministically
    let dominating = 400.0;
    let base = DiurnalPattern::new(100.0);
    for seed in [3u64, 17, 1234] {
        let mut counts = Vec::new();
        for scale in [1.0, 2.0, 4.0] {
            let pattern = base.scaled(scale);
            let mut gen = NonHomogeneousArrivals::with_dominating_rate(
                pattern, dominating, seed,
            );
            counts.push(gen.times_until(2_000.0).len());
        }
        assert!(
            counts[0] <= counts[1] && counts[1] <= counts[2],
            "seed {seed}: counts {counts:?} must be monotone in rate scale"
        );
        // and the superset property holds arrival-by-arrival
        let lo: Vec<f64> = NonHomogeneousArrivals::with_dominating_rate(
            base.clone(),
            dominating,
            seed,
        )
        .times_until(2_000.0);
        let hi: Vec<f64> = NonHomogeneousArrivals::with_dominating_rate(
            base.scaled(4.0),
            dominating,
            seed,
        )
        .times_until(2_000.0);
        let mut j = 0;
        for t in &lo {
            while j < hi.len() && hi[j] < *t {
                j += 1;
            }
            assert!(
                j < hi.len() && hi[j] == *t,
                "seed {seed}: low-rate arrival {t} missing from scaled stream"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Thread-count invariance of single- and multi-tenant sweeps
// ---------------------------------------------------------------------

#[test]
fn parallel_sim_sweep_identical_across_thread_counts() {
    let p = real::img_to_text();
    let cluster = ClusterSpec::two_2080ti();
    let d = spread(16, CommMode::GlobalIpc);
    let opts = SimOptions { queries: 600, ..Default::default() };
    let sim = Simulator::new(&p, &cluster, &d, opts);
    let rates: Vec<f64> = (1..=8).map(|i| 40.0 * i as f64).collect();
    let sweep = |threads: usize| {
        par_map_threads(&rates, threads, |_, &rate| {
            let rep = sim.run(rate).unwrap();
            (
                rep.completed,
                rep.p99().to_bits(),
                rep.breakdown.total().to_bits(),
            )
        })
    };
    let serial = sweep(1);
    for threads in [2, 4, 7] {
        assert_eq!(serial, sweep(threads), "sweep differs at {threads} threads");
    }
}

#[test]
fn colocated_sweep_identical_across_thread_counts() {
    // the ISSUE-2 determinism satellite: fan a co-located two-tenant
    // load grid across 1/2/8 workers — every cell must come back
    // bit-identical, constant and diurnal arrivals alike
    let pa = real::img_to_text();
    let pb = real::text_to_text();
    let cluster = ClusterSpec::two_2080ti();
    let (da, db) = half_cluster_pair(16);
    let opts = SimOptions { queries: 500, ..Default::default() };
    let cells: Vec<(f64, f64, bool)> = (1..=4)
        .flat_map(|i| {
            let a = 30.0 * i as f64;
            [(a, 45.0, false), (a, 90.0, false), (a, 60.0, true)]
        })
        .collect();
    let sweep = |threads: usize| {
        par_map_threads(&cells, threads, |_, &(ra, rb, diurnal)| {
            let arr = |rate: f64| {
                if diurnal {
                    ArrivalProcess::diurnal(DiurnalPattern {
                        peak_qps: rate,
                        trough_frac: 0.3,
                        period_s: 90.0,
                    })
                } else {
                    ArrivalProcess::constant(rate)
                }
            };
            let reps = ClusterSim::new(
                &cluster,
                vec![
                    TenantSpec { pipeline: &pa, deployment: &da, arrivals: arr(ra) },
                    TenantSpec { pipeline: &pb, deployment: &db, arrivals: arr(rb) },
                ],
                opts.clone(),
            )
            .run()
            .unwrap();
            (
                reps[0].completed,
                reps[0].p99().to_bits(),
                reps[0].breakdown.total().to_bits(),
                reps[1].completed,
                reps[1].p99().to_bits(),
                reps[1].breakdown.total().to_bits(),
            )
        })
    };
    let serial = sweep(1);
    for threads in [2, 8] {
        assert_eq!(
            serial,
            sweep(threads),
            "co-located sweep differs at {threads} threads"
        );
    }
}

#[test]
fn speculative_peak_search_identical_across_thread_counts() {
    let p = real::img_to_text();
    let cluster = ClusterSpec::two_2080ti();
    let d = colocated(16, CommMode::GlobalIpc);
    let opts = SimOptions { queries: 600, ..Default::default() };
    let sim = Simulator::new(&p, &cluster, &d, opts);
    let search = |threads: usize| {
        workload::peak_load_search_bracketed(
            |rates| {
                par_map_threads(rates, threads, |_, &rate| {
                    sim.run(rate).map(|r| r.p99()).unwrap_or(f64::INFINITY)
                })
            },
            p.qos_target_s,
            50.0,
            2_000.0,
            0.03,
            3,
        )
    };
    let (peak1, trials1) = search(1);
    for threads in [3, 8] {
        let (peak_n, trials_n) = search(threads);
        assert_eq!(peak1.to_bits(), peak_n.to_bits(), "{threads} threads");
        assert_eq!(trials1.len(), trials_n.len());
        for (a, b) in trials1.iter().zip(&trials_n) {
            assert_eq!(a.rate_qps.to_bits(), b.rate_qps.to_bits());
            assert_eq!(a.p99_s.to_bits(), b.p99_s.to_bits());
            assert_eq!(a.qos_met, b.qos_met);
        }
    }
    assert!(peak1 > 0.0, "search must find a feasible load");
}
