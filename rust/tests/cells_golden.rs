//! Golden suite for the sharded cluster-of-cells scale-out (PR 6):
//!
//! * `replay_trace_cells` with `cells = 1` is **bit-identical** to the
//!   flat `replay_trace` — same decisions, same interval measurements,
//!   same summary, on both a generated admission trace and the crafted
//!   repeated-configuration trace;
//! * a multi-cell replay (16-GPU fleet, 4 cells) is bit-identical
//!   across 1/2/8 worker threads — merged report, per-cell stats, and
//!   routing assignments alike (all placement happens in sequential
//!   phase 1; all seeds are fixed before the phase-2 fan);
//! * per-cell interval dedup is bit-identical on and off (same contract
//!   the flat replay pins);
//! * the router's tenant→cell assignments are deterministic — the
//!   least-utilized-feasible policy with index tie-break never depends
//!   on the thread budget.

use camelot::config::ClusterSpec;
use camelot::coordinator::admission::{replay_trace, ReplayConfig};
use camelot::coordinator::{replay_trace_cells, AdmissionConfig, CellsConfig, CellsReplayConfig};
use camelot::suite::workload::{
    ArrivalProcess, Priority, TenantTrace, TenantTraceConfig, TenantTraceEvent, TraceEventKind,
};

fn flat_cfg(queries: usize, threads: usize) -> ReplayConfig {
    ReplayConfig { queries, threads, ..Default::default() }
}

fn cells_cfg(cells: usize, queries: usize, threads: usize, dedup: bool) -> CellsReplayConfig {
    CellsReplayConfig {
        router: CellsConfig { cells, ..Default::default() },
        queries,
        threads,
        dedup,
        audit_qos: false,
        ..Default::default()
    }
}

/// The five-tenant generated trace the flat golden suite uses.
fn generated_trace(seed: u64) -> TenantTrace {
    TenantTrace::generate(
        &TenantTraceConfig {
            tenants: 5,
            mean_interarrival_s: 300.0,
            mean_lifetime_s: 900.0,
            peak_qps_lo: 40.0,
            peak_qps_hi: 110.0,
            ..Default::default()
        },
        seed,
    )
}

/// A busier trace for the 16-GPU multi-cell fleet: enough concurrent
/// tenants that several cells hold residents at once.
fn fleet_trace() -> TenantTrace {
    TenantTrace::generate(
        &TenantTraceConfig {
            tenants: 10,
            mean_interarrival_s: 120.0,
            mean_lifetime_s: 900.0,
            peak_qps_lo: 40.0,
            peak_qps_hi: 100.0,
            ..Default::default()
        },
        7,
    )
}

#[test]
fn single_cell_replay_is_bit_identical_to_flat_replay() {
    let cluster = ClusterSpec::two_2080ti();
    for (tag, trace) in [
        ("generated", &generated_trace(2024)),
        ("repeated", &TenantTrace::repeated_cycle()),
    ] {
        let flat = replay_trace(&cluster, trace, &flat_cfg(300, 1)).expect("flat replay");
        for threads in [1usize, 2, 8] {
            let sharded =
                replay_trace_cells(&cluster, trace, &cells_cfg(1, 300, threads, true))
                    .expect("sharded replay");
            assert_eq!(sharded.cells, 1);
            assert_eq!(sharded.migrations, 0, "{tag}: one cell has nowhere to migrate");
            assert_eq!(
                flat.fingerprint(),
                sharded.merged.fingerprint(),
                "{tag}: cells=1 differs from the flat controller at {threads} threads"
            );
            // the caches see the identical request stream too
            assert_eq!(
                (flat.solve_cache.hits, flat.solve_cache.misses),
                (sharded.merged.solve_cache.hits, sharded.merged.solve_cache.misses),
                "{tag}: solve-cache traffic drifts at {threads} threads"
            );
            assert_eq!(flat.intervals_simulated, sharded.merged.intervals_simulated);
        }
    }
}

#[test]
fn multi_cell_replay_is_bit_identical_across_threads() {
    let cluster = ClusterSpec::dgx2(); // 16 GPUs -> 4 cells of 4
    let trace = fleet_trace();
    let baseline = replay_trace_cells(&cluster, &trace, &cells_cfg(4, 200, 1, true))
        .expect("sharded replay");
    assert_eq!(baseline.per_cell.len(), 4);
    assert!(
        baseline.merged.admitted > 0,
        "fleet trace must admit tenants: {:?}",
        baseline.merged
    );
    // the fleet must actually spread across cells, or the determinism
    // claim is vacuously about one shard
    let used: std::collections::BTreeSet<usize> =
        baseline.tenant_cells.iter().map(|&(_, c)| c).collect();
    assert!(used.len() > 1, "all tenants landed in one cell: {:?}", baseline.tenant_cells);
    for threads in [2usize, 8] {
        let rep = replay_trace_cells(&cluster, &trace, &cells_cfg(4, 200, threads, true))
            .expect("sharded replay");
        assert_eq!(
            baseline.merged.fingerprint(),
            rep.merged.fingerprint(),
            "merged report differs at {threads} threads"
        );
        assert_eq!(
            format!("{:?}", baseline.per_cell),
            format!("{:?}", rep.per_cell),
            "per-cell stats differ at {threads} threads"
        );
        assert_eq!(
            baseline.tenant_cells, rep.tenant_cells,
            "routing differs at {threads} threads"
        );
        assert_eq!(baseline.migrations, rep.migrations);
    }
}

/// A hand-built chaos trace: a best-effort tier, a nested flash crowd,
/// a GPU failure and its recovery — every chaos event kind on one
/// timeline.
fn chaos_trace() -> TenantTrace {
    let mk = |t_s: f64, tenant: u64, kind: TraceEventKind| TenantTraceEvent { t_s, tenant, kind };
    TenantTrace {
        events: vec![
            mk(
                0.0,
                0,
                TraceEventKind::Arrive {
                    pipeline: "img-to-text".into(),
                    name: None,
                    arrivals: ArrivalProcess::constant(100.0),
                    plan_qps: 100.0,
                    priority: Priority::LatencyCritical,
                },
            ),
            mk(
                10.0,
                1,
                TraceEventKind::Arrive {
                    pipeline: "text-to-text".into(),
                    name: None,
                    arrivals: ArrivalProcess::constant(70.0),
                    plan_qps: 70.0,
                    priority: Priority::BestEffort,
                },
            ),
            mk(100.0, 0, TraceEventKind::Burst { rate_mult: 1.5, duration_s: 60.0 }),
            // nested: opens inside the first window, closes first
            mk(120.0, 0, TraceEventKind::Burst { rate_mult: 2.0, duration_s: 20.0 }),
            mk(200.0, 0, TraceEventKind::GpuFail { gpu_ids: vec![0] }),
            mk(300.0, 0, TraceEventKind::GpuRecover { gpu_ids: vec![0] }),
            mk(400.0, 1, TraceEventKind::Depart),
            mk(500.0, 0, TraceEventKind::Depart),
        ],
    }
}

#[test]
fn chaos_trace_replay_matches_flat_across_threads_and_modes() {
    let cluster = ClusterSpec::two_2080ti();
    let trace = chaos_trace();
    let flat = replay_trace(&cluster, &trace, &flat_cfg(200, 1)).expect("flat replay");
    // the trace must actually exercise the chaos paths (synthesized
    // burst ends included), or the equality below proves nothing
    assert!(flat.events.iter().any(|e| e.desc.starts_with("burst x")));
    assert!(
        flat.events.iter().any(|e| e.decision == "nested burst still open"),
        "nested burst window must close inner-first: {:?}",
        flat.events.iter().map(|e| (&e.desc, &e.decision)).collect::<Vec<_>>()
    );
    assert!(flat.events.iter().any(|e| e.decision.starts_with("offered load restored")));
    assert!(flat.events.iter().any(|e| e.desc.starts_with("gpufail")));
    assert!(flat.events.iter().any(|e| e.desc.starts_with("gpurecover")));
    for threads in [2usize, 8] {
        let rep = replay_trace(&cluster, &trace, &flat_cfg(200, threads)).expect("replay");
        assert_eq!(
            flat.fingerprint(),
            rep.fingerprint(),
            "flat chaos replay differs at {threads} threads"
        );
    }
    for threads in [1usize, 2, 8] {
        let sharded =
            replay_trace_cells(&cluster, &trace, &cells_cfg(1, 200, threads, true))
                .expect("sharded replay");
        assert_eq!(
            flat.fingerprint(),
            sharded.merged.fingerprint(),
            "cells=1 chaos replay differs from flat at {threads} threads"
        );
    }
}

#[test]
fn multi_cell_chaos_replay_is_thread_count_invariant() {
    let cluster = ClusterSpec::dgx2(); // 16 GPUs -> 4 cells of 4
    let mut trace = fleet_trace();
    // splice chaos into the generated day: a correlated flash crowd on
    // two tenants plus a failure spanning two cells and its recovery
    // (the replay's burst expansion canonically re-sorts the timeline)
    trace.events.push(TenantTraceEvent {
        t_s: 1_000.0,
        tenant: 0,
        kind: TraceEventKind::Burst { rate_mult: 2.0, duration_s: 300.0 },
    });
    trace.events.push(TenantTraceEvent {
        t_s: 1_000.0,
        tenant: 1,
        kind: TraceEventKind::Burst { rate_mult: 2.0, duration_s: 300.0 },
    });
    trace.events.push(TenantTraceEvent {
        t_s: 1_500.0,
        tenant: 0,
        kind: TraceEventKind::GpuFail { gpu_ids: vec![0, 5] },
    });
    trace.events.push(TenantTraceEvent {
        t_s: 2_000.0,
        tenant: 0,
        kind: TraceEventKind::GpuRecover { gpu_ids: vec![0, 5] },
    });
    let baseline = replay_trace_cells(&cluster, &trace, &cells_cfg(4, 200, 1, true))
        .expect("sharded replay");
    for threads in [2usize, 8] {
        let rep = replay_trace_cells(&cluster, &trace, &cells_cfg(4, 200, threads, true))
            .expect("sharded replay");
        assert_eq!(
            baseline.merged.fingerprint(),
            rep.merged.fingerprint(),
            "multi-cell chaos replay differs at {threads} threads"
        );
        assert_eq!(baseline.tenant_cells, rep.tenant_cells);
    }
}

#[test]
fn per_cell_dedup_is_bit_identical_on_and_off() {
    let cluster = ClusterSpec::dgx2();
    let trace = fleet_trace();
    let deduped = replay_trace_cells(&cluster, &trace, &cells_cfg(4, 200, 0, true))
        .expect("sharded replay");
    let mut uncached = cells_cfg(4, 200, 0, false);
    uncached.router.admission = AdmissionConfig { solve_cache: 0, ..Default::default() };
    let full = replay_trace_cells(&cluster, &trace, &uncached).expect("sharded replay");
    assert_eq!(full.merged.solve_cache.hits, 0, "disabled cache must not hit");
    assert_eq!(
        full.merged.intervals_simulated,
        full.merged.intervals.len(),
        "dedup off simulates every interval"
    );
    assert_eq!(
        deduped.merged.fingerprint(),
        full.merged.fingerprint(),
        "dedup + memoization change cell-sharded results"
    );
    assert_eq!(deduped.tenant_cells, full.tenant_cells);
}
