//! Cross-module integration tests: the full plan → deploy → simulate
//! protocol, the paper's headline orderings, and end-to-end invariants
//! that only hold when every layer composes.
//!
//! These run the same machinery as the figure harnesses, scaled down to
//! keep `cargo test` fast.

use camelot::allocator::{max_load, min_resource, AllocContext, SaParams};
use camelot::baselines::{plan, Planner};
use camelot::comm::CommMode;
use camelot::config::ClusterSpec;
use camelot::deploy;
use camelot::figures::common::{
    peak_load, plan_low_load, planner_peak, train_predictors,
};
use camelot::planner::ClusterState;
use camelot::sim::{SimOptions, Simulator};
use camelot::suite::{artifact, real};
use camelot::util::testkit;

fn opts() -> SimOptions {
    SimOptions { queries: 2_000, warmup_frac: 0.15, ..Default::default() }
}

#[test]
fn camelot_beats_ea_on_every_real_benchmark() {
    // The Fig 14 headline: Camelot's supported peak exceeds EA's
    // (paper: +12% to +73.9%) while honoring the 99%-ile QoS.
    let cluster = ClusterSpec::two_2080ti();
    for p in real::all() {
        let preds = train_predictors(&p, &cluster);
        let (_, ea_peak, _) =
            planner_peak(Planner::EvenAllocation, &p, &cluster, &preds, 16, &opts()).unwrap();
        let (_, cam_peak, cam_report) =
            planner_peak(Planner::Camelot, &p, &cluster, &preds, 16, &opts()).unwrap();
        assert!(
            cam_peak > ea_peak,
            "{}: camelot {cam_peak} must beat EA {ea_peak}",
            p.name
        );
        assert!(
            cam_report.p99() <= p.qos_target_s * 1.05,
            "{}: camelot p99 {} at its peak must respect QoS {}",
            p.name,
            cam_report.p99(),
            p.qos_target_s
        );
    }
}

#[test]
fn camelot_reduces_low_load_resource_usage() {
    // The Fig 16 headline: at 30% load Camelot uses materially less than
    // a GPU per stage (paper: −46.5% average) and still meets QoS.
    let cluster = ClusterSpec::two_2080ti();
    let mut savings = Vec::new();
    for p in real::all() {
        let preds = train_predictors(&p, &cluster);
        let (_, peak, _) =
            planner_peak(Planner::Camelot, &p, &cluster, &preds, 32, &opts()).unwrap();
        let low = peak * 0.3;
        let d = plan_low_load(Planner::Camelot, &p, &cluster, &preds, 32, low).unwrap();
        let usage = d.total_sm_usage() / p.n_stages() as f64;
        assert!(usage < 1.0, "{}: normalized usage {usage}", p.name);
        let rep = Simulator::new(&p, &cluster, &d, opts()).run(low.max(1.0)).unwrap();
        assert!(
            rep.p99() <= p.qos_target_s * 1.1,
            "{}: p99 {} at low load",
            p.name,
            rep.p99()
        );
        savings.push(1.0 - usage);
    }
    let avg = savings.iter().sum::<f64>() / savings.len() as f64;
    assert!(avg > 0.25, "average saving {avg} should be substantial");
}

#[test]
fn case2_allocation_deploys_and_meets_qos_in_sim() {
    let p = real::text_to_text();
    let cluster = ClusterSpec::two_2080ti();
    let preds = train_predictors(&p, &cluster);
    let ctx = AllocContext::new(&p, &cluster, &preds, 16);
    let (r, gpus) = min_resource::solve(&ctx, 80.0, SaParams::default()).unwrap();
    assert!(gpus >= 1);
    let d = deploy::deploy(
        &p,
        &ClusterState::exclusive(&cluster),
        &r.best,
        16,
        CommMode::GlobalIpc,
        None,
    )
    .unwrap();
    let rep = Simulator::new(&p, &cluster, &d, opts()).run(80.0).unwrap();
    assert!(rep.p99() <= p.qos_target_s, "p99 {} > QoS", rep.p99());
}

#[test]
fn ipc_comm_strictly_helps_heavy_pipelines() {
    // §VI: for payload-heavy pipelines, switching the same deployment
    // from main-memory to IPC communication lifts the supported peak.
    let p = real::img_to_img();
    let cluster = ClusterSpec::two_2080ti();
    let preds = train_predictors(&p, &cluster);
    let base = plan(Planner::Camelot, &p, &cluster, &preds, 32, SaParams::default()).unwrap();
    let mut mm = base.clone();
    mm.comm = CommMode::MainMemory;
    let (peak_ipc, _) = peak_load(&p, &cluster, &base, &opts());
    let (peak_mm, _) = peak_load(&p, &cluster, &mm, &opts());
    assert!(
        peak_ipc >= peak_mm,
        "ipc peak {peak_ipc} must be at least main-memory peak {peak_mm}"
    );
}

#[test]
fn nc_ablation_admits_bandwidth_saturating_plans() {
    // §VIII-D: disabling the bandwidth constraint widens the feasible
    // set (that is exactly why it then violates QoS at runtime).
    let p = artifact::pipeline(1, 1, 3); // heavy memory stage
    let cluster = ClusterSpec::two_2080ti();
    let preds = train_predictors(&p, &cluster);
    let mut with_bw = AllocContext::new(&p, &cluster, &preds, 32);
    with_bw.enforce_bw = true;
    let mut without_bw = AllocContext::new(&p, &cluster, &preds, 32);
    without_bw.enforce_bw = false;
    let a = max_load::solve(&with_bw, SaParams::default()).unwrap();
    let b = max_load::solve(&without_bw, SaParams::default()).unwrap();
    // NC's *predicted* objective can only be ≥ Camelot's
    assert!(b.best_objective >= a.best_objective * 0.95);
}

#[test]
fn artifact_pipelines_full_protocol_smoke() {
    // one composite per PCIe level, full plan→deploy→simulate protocol
    let cluster = ClusterSpec::two_2080ti();
    for (pi, cj, mk) in [(1, 1, 1), (2, 2, 2), (3, 3, 3)] {
        let p = artifact::pipeline(pi, cj, mk);
        let preds = train_predictors(&p, &cluster);
        let (_, peak, rep) =
            planner_peak(Planner::Camelot, &p, &cluster, &preds, 32, &opts())
                .unwrap_or_else(|| panic!("{} plans", p.name));
        assert!(peak > 0.0, "{}: peak {peak}", p.name);
        assert!(rep.p99() <= p.qos_target_s * 1.05, "{}", p.name);
    }
}

#[test]
fn dgx2_scales_beyond_two_gpus() {
    // Fig 19: the same machinery on 16×V100 must support a higher peak
    // than on 2×2080Ti.
    let p = real::img_to_img();
    let small = ClusterSpec::two_2080ti();
    let big = ClusterSpec::dgx2();
    let preds_s = train_predictors(&p, &small);
    let preds_b = train_predictors(&p, &big);
    let (_, peak_s, _) =
        planner_peak(Planner::Camelot, &p, &small, &preds_s, 16, &opts()).unwrap();
    let (_, peak_b, _) =
        planner_peak(Planner::Camelot, &p, &big, &preds_b, 16, &opts()).unwrap();
    assert!(
        peak_b > peak_s * 1.5,
        "dgx2 peak {peak_b} should scale past 2-GPU peak {peak_s}"
    );
}

#[test]
fn deployments_never_oversubscribe_property() {
    // Any allocation the planner emits must placement-validate and
    // sim-admit across random batch sizes and pipelines.
    let cluster = ClusterSpec::two_2080ti();
    let pipelines = real::all();
    let preds: Vec<_> = pipelines
        .iter()
        .map(|p| train_predictors(p, &cluster))
        .collect();
    testkit::forall_res(
        99,
        8,
        |r| (r.below(pipelines.len()), 8u32 << r.below(3), r.next_u64()),
        |&(pi, batch, seed)| {
            let p = &pipelines[pi];
            let sa = SaParams { seed, iterations: 800, ..Default::default() };
            let d = plan(Planner::Camelot, p, &cluster, &preds[pi], batch, sa)
                .map_err(|e| format!("plan: {e}"))?;
            let sim = Simulator::new(p, &cluster, &d, SimOptions { queries: 1, ..Default::default() });
            let gpus = sim.admit().map_err(|e| format!("admit: {e}"))?;
            for g in &gpus {
                if g.sm_allocated() > 1.0 + 1e-9 {
                    return Err(format!("SM oversubscribed: {}", g.sm_allocated()));
                }
                if g.mem_free() < 0.0 {
                    return Err("memory oversubscribed".into());
                }
                if g.contexts() > 48 {
                    return Err(format!("context limit: {}", g.contexts()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn simulation_conserves_queries() {
    // every injected request leaves the system exactly once
    let p = real::img_to_text();
    let cluster = ClusterSpec::two_2080ti();
    let preds = train_predictors(&p, &cluster);
    let d = plan(Planner::Camelot, &p, &cluster, &preds, 16, SaParams::default()).unwrap();
    for load in [40.0, 400.0, 4_000.0] {
        let o = SimOptions { queries: 1_600, ..Default::default() };
        let rep = Simulator::new(&p, &cluster, &d, o).run(load).unwrap();
        assert_eq!(rep.completed, 100, "all requests complete at {load} qps");
    }
}
