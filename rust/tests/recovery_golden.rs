//! Golden suite for the durable control plane (`coordinator::recovery`):
//!
//! * **Crash recovery is exact** — killing the durable controller at
//!   *every* event boundary and recovering (latest snapshot + WAL tail)
//!   reconverges bit-identically to the uninterrupted replay
//!   ([`ReplayReport::fingerprint`] equality), flat and through the
//!   4-cell router, across 1/2/8 worker threads;
//! * **WAL-off is free** — a durable replay that runs to completion
//!   (and a recovery from its completed store) fingerprints identically
//!   to the plain in-memory replay, so durability is pure observation;
//! * **Snapshots round-trip** — serialize → restore at a mid-trace
//!   boundary preserves the fingerprint for every snapshotted state
//!   shape: legacy, heterogeneous pools, MIG-sliced pools, and
//!   KV-bearing LLM co-location;
//! * **Degraded plans stay deterministic** — a tiny `plan_deadline`
//!   budget forces the greedy fallback, and the degraded replay is
//!   reproducible, thread-invariant, and crash-recoverable;
//! * **Warm caches don't change decisions** — a solve-cache payload
//!   extracted from one replay warm-starts the next bit-identically,
//!   with the cache counters (and only those) moving.

use camelot::config::{ClusterSpec, GpuClass, GpuSpec, PartitionMode, SliceCatalog};
use camelot::coordinator::admission::{replay_trace, ReplayConfig, ReplayState};
use camelot::coordinator::cells::CellsReplayState;
use camelot::coordinator::recovery::trace_event_list;
use camelot::coordinator::{
    recover, replay_durable, replay_durable_cells, replay_trace_cells, verify_crash_recovery,
    verify_crash_recovery_cells, CellsReplayConfig, DirStore, MemStore,
};
use camelot::planner::ScenarioSpec;
use camelot::suite::workload::{
    ArrivalProcess, Priority, TenantTrace, TenantTraceConfig, TenantTraceEvent, TraceEventKind,
};
use camelot::util::json::Json;

const THREAD_MATRIX: [usize; 3] = [1, 2, 8];

fn small_trace(seed: u64) -> TenantTrace {
    TenantTrace::generate(
        &TenantTraceConfig {
            tenants: 5,
            mean_interarrival_s: 300.0,
            mean_lifetime_s: 900.0,
            peak_qps_lo: 40.0,
            peak_qps_hi: 110.0,
            ..Default::default()
        },
        seed,
    )
}

fn fast_cfg(threads: usize) -> ReplayConfig {
    ReplayConfig { queries: 100, threads, ..Default::default() }
}

/// A hand-built trace that exercises the chaos events the WAL must
/// carry: bursts of load, partial GPU degrades, and a full fail/recover
/// cycle, interleaved with shrink and departure.
fn chaos_trace() -> TenantTrace {
    let mk = |t_s: f64, tenant: u64, kind: TraceEventKind| TenantTraceEvent { t_s, tenant, kind };
    let arrive = |pipeline: &str, qps: f64| TraceEventKind::Arrive {
        pipeline: pipeline.into(),
        name: None,
        arrivals: ArrivalProcess::constant(qps),
        plan_qps: qps,
        priority: Priority::LatencyCritical,
    };
    TenantTrace {
        events: vec![
            mk(0.0, 0, arrive("img-to-text", 100.0)),
            mk(30.0, 1, arrive("text-to-text", 60.0)),
            mk(60.0, 0, TraceEventKind::GpuDegrade { gpu_ids: vec![0], scale: 1.4 }),
            mk(90.0, 2, arrive("img-to-img", 40.0)),
            mk(120.0, 0, TraceEventKind::GpuRestore { gpu_ids: vec![0] }),
            mk(150.0, 0, TraceEventKind::Shrink { target_qps: 40.0 }),
            mk(180.0, 0, TraceEventKind::GpuFail { gpu_ids: vec![1] }),
            mk(210.0, 1, TraceEventKind::Depart),
            mk(240.0, 0, TraceEventKind::GpuRecover { gpu_ids: vec![1] }),
        ],
    }
}

// ---------------------------------------------------------------------
// Crash-recovery goldens (the tentpole contract)
// ---------------------------------------------------------------------

/// Flat controller: kill at every event boundary (0..=n, n = before the
/// measurement phase), recover, and require fingerprint equality — for
/// every thread count in the matrix.
#[test]
fn crash_recovery_reconverges_at_every_boundary_flat() {
    let cluster = ClusterSpec::two_2080ti();
    let trace = small_trace(2024);
    for threads in THREAD_MATRIX {
        verify_crash_recovery(&cluster, &trace, &fast_cfg(threads), 2, &[], &[])
            .unwrap_or_else(|e| panic!("flat crash golden at {threads} threads: {e}"));
    }
}

/// Cells router (4 cells on an 8-GPU pool): same every-boundary
/// contract, plus routing and migration equality (checked inside the
/// harness).
#[test]
fn crash_recovery_reconverges_at_every_boundary_cells() {
    let cluster = ClusterSpec { num_gpus: 8, ..ClusterSpec::two_2080ti() };
    let trace = small_trace(7);
    for threads in THREAD_MATRIX {
        let cfg = CellsReplayConfig::from_replay(4, &fast_cfg(threads));
        verify_crash_recovery_cells(&cluster, &trace, &cfg, 2, &[], &[])
            .unwrap_or_else(|e| panic!("cells crash golden at {threads} threads: {e}"));
    }
}

/// Chaos events (degrade/restore, fail/recover, shrink, depart) are
/// WAL-serializable and crash-recoverable; snapshot cadence 0 (WAL-only
/// recovery) and 3 both reconverge.
#[test]
fn crash_recovery_covers_chaos_events_and_all_cadences() {
    let cluster = ClusterSpec::two_2080ti();
    let trace = chaos_trace();
    for snapshot_every in [0usize, 3] {
        verify_crash_recovery(&cluster, &trace, &fast_cfg(1), snapshot_every, &[], &[])
            .unwrap_or_else(|e| panic!("chaos crash golden (cadence {snapshot_every}): {e}"));
    }
}

// ---------------------------------------------------------------------
// WAL-off byte-identity
// ---------------------------------------------------------------------

/// A durable replay that is never killed — and a recovery over its
/// completed store — both fingerprint identically to the plain replay:
/// the WAL is observation only.
#[test]
fn durable_and_recovered_replays_match_the_plain_replay() {
    let cluster = ClusterSpec::two_2080ti();
    let trace = small_trace(2024);
    let cfg = fast_cfg(1);
    let golden = replay_trace(&cluster, &trace, &cfg).expect("plain replay").fingerprint();

    let mut store = MemStore::new();
    let durable = replay_durable(&cluster, &trace, &cfg, &mut store, 2, None)
        .expect("durable replay")
        .expect("no crash injected");
    assert_eq!(golden, durable.fingerprint(), "durable replay drifted from plain");

    // recovery over the completed store replays nothing new but must
    // still verify every WAL record and land on the same report
    let recovered = recover(&cluster, &trace, &cfg, &mut store, &[]).expect("recover");
    assert_eq!(golden, recovered.fingerprint(), "post-completion recovery drifted");

    // the on-disk store behaves like the in-memory one
    let dir = std::env::temp_dir().join("camelot-recovery-golden-dirstore");
    let _ = std::fs::remove_dir_all(&dir);
    let mut disk = DirStore::open(&dir).expect("open store");
    let on_disk = replay_durable(&cluster, &trace, &cfg, &mut disk, 2, None)
        .expect("durable replay (disk)")
        .expect("no crash injected");
    assert_eq!(golden, on_disk.fingerprint(), "DirStore replay drifted");
    assert!(dir.join("wal.log").is_file(), "WAL file must exist");
    let mut disk = DirStore::open(&dir).expect("re-open store");
    let recovered = recover(&cluster, &trace, &cfg, &mut disk, &[]).expect("recover from disk");
    assert_eq!(golden, recovered.fingerprint(), "DirStore recovery drifted");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Snapshot serialize → restore identity (satellite property test)
// ---------------------------------------------------------------------

/// Snapshot a mid-replay state, restore it from the JSON, continue both
/// to the end, and require fingerprint equality.
fn assert_snapshot_roundtrip(cluster: &ClusterSpec, trace: &TenantTrace, cfg: &ReplayConfig) {
    let events = trace_event_list(trace);
    let cut = events.len() / 2;
    let mut original = ReplayState::new(cluster, cfg.clone());
    original.warm_start().expect("warm start");
    for e in &events[..cut] {
        original.apply_event(e).expect("apply");
    }
    let snap = original.snapshot_json();
    let v = Json::parse(&snap).expect("snapshot parses");
    let mut restored = ReplayState::restore(cluster, cfg.clone(), &v, &[])
        .unwrap_or_else(|e| panic!("restore: {e}"));
    assert_eq!(original.applied(), restored.applied(), "restored WAL cursor drifted");
    // the restored snapshot re-serializes byte-identically
    assert_eq!(snap, restored.snapshot_json(), "snapshot not a serialization fixed point");
    for e in &events[cut..] {
        original.apply_event(e).expect("apply original");
        restored.apply_event(e).expect("apply restored");
    }
    let a = original.finish().expect("finish original").fingerprint();
    let b = restored.finish().expect("finish restored").fingerprint();
    assert_eq!(a, b, "restored replay diverged after the snapshot cut");
}

#[test]
fn snapshot_roundtrip_preserves_legacy_state() {
    let cluster = ClusterSpec::two_2080ti();
    assert_snapshot_roundtrip(&cluster, &small_trace(2024), &fast_cfg(1));
    assert_snapshot_roundtrip(&cluster, &chaos_trace(), &fast_cfg(1));
}

#[test]
fn snapshot_roundtrip_preserves_hetero_and_mig_state() {
    // mixed classes: two 2080Ti + two A100 at a different compute scale
    let base = ClusterSpec::two_2080ti();
    let mut mixed = ClusterSpec { num_gpus: 4, ..base.clone() };
    mixed.classes = vec![
        GpuClass::scaled(base.gpu.clone(), 2, 1.0),
        GpuClass::scaled(GpuSpec::a100_sxm4_80g(), 2, 0.7),
    ];
    mixed.validate_classes().unwrap();
    assert!(!mixed.effectively_homogeneous());
    assert_snapshot_roundtrip(&mixed, &small_trace(7), &fast_cfg(1));

    // MIG-sliced pool: quotas live on the discrete slice grid
    let mut mig = ClusterSpec { num_gpus: 2, ..base };
    mig.partition = PartitionMode::Discrete(SliceCatalog::mig7());
    assert_snapshot_roundtrip(&mig, &small_trace(11), &fast_cfg(1));
}

#[test]
fn snapshot_roundtrip_preserves_llm_kv_state() {
    let spec = ScenarioSpec::parse(
        r#"{
        "name": "recovery-llm-golden",
        "cluster": {"preset": "2080ti", "gpus": 8},
        "batch": 16,
        "seed": 11,
        "queries": 100,
        "tenants": [
            {"name": "chat", "workload": "llm", "plan_qps": 8.0,
             "arrivals": "constant", "arrive_s": 0.0},
            {"name": "search", "pipeline": "img-to-text", "plan_qps": 40.0,
             "arrivals": "diurnal", "arrive_s": 5.0, "depart_s": 600.0},
            {"name": "chat-batch", "workload": "llm", "plan_qps": 6.0,
             "prompt_tokens": 256, "output_tokens": 64,
             "kv_bytes_per_token": 131072,
             "arrivals": "constant", "arrive_s": 10.0}
        ]
    }"#,
    )
    .expect("spec parses");
    let mut cfg = fast_cfg(1);
    cfg.queries = spec.queries;
    cfg.admission.seed = spec.seed;
    cfg.admission.batch = spec.batch;
    assert_snapshot_roundtrip(&spec.cluster, &spec.trace(), &cfg);
    // and the KV-bearing trace is crash-recoverable end to end
    verify_crash_recovery(&spec.cluster, &spec.trace(), &cfg, 2, &[], &[])
        .unwrap_or_else(|e| panic!("LLM crash golden: {e}"));
}

/// Cells snapshots round-trip too: restore at a mid-trace cut and the
/// sharded replay reconverges, router state included.
#[test]
fn snapshot_roundtrip_preserves_cells_state() {
    let cluster = ClusterSpec { num_gpus: 8, ..ClusterSpec::two_2080ti() };
    let trace = small_trace(7);
    let cfg = CellsReplayConfig::from_replay(4, &fast_cfg(1));
    let events = trace_event_list(&trace);
    let cut = events.len() / 2;
    let mut original = CellsReplayState::new(&cluster, cfg.clone()).expect("state");
    for e in &events[..cut] {
        original.apply_event(e).expect("apply");
    }
    let snap = original.snapshot_json();
    let v = Json::parse(&snap).expect("snapshot parses");
    let mut restored = CellsReplayState::restore(&cluster, cfg, &v, &[])
        .unwrap_or_else(|e| panic!("restore: {e}"));
    assert_eq!(snap, restored.snapshot_json(), "cells snapshot not a fixed point");
    for e in &events[cut..] {
        original.apply_event(e).expect("apply original");
        restored.apply_event(e).expect("apply restored");
    }
    let a = original.finish().expect("finish original");
    let b = restored.finish().expect("finish restored");
    assert_eq!(a.merged.fingerprint(), b.merged.fingerprint(), "cells replay diverged");
    assert_eq!(a.tenant_cells, b.tenant_cells, "tenant routing diverged");
    assert_eq!(a.migrations, b.migrations, "migration count diverged");
}

// ---------------------------------------------------------------------
// plan_deadline: deterministic degradation
// ---------------------------------------------------------------------

/// A tiny SA budget forces the greedy Case-1 fallback on admission
/// solves; the degraded replay must be reproducible, thread-invariant,
/// and crash-recoverable — degradation never trades determinism away.
#[test]
fn plan_deadline_degrades_deterministically() {
    let cluster = ClusterSpec::two_2080ti();
    let trace = small_trace(2024);
    let mut cfg = fast_cfg(1);
    cfg.admission.plan_deadline = 1; // every real solve exceeds this
    let baseline = replay_trace(&cluster, &trace, &cfg).expect("degraded replay");
    // the budget actually bit: at least one decision took the fallback
    let events = trace_event_list(&trace);
    let mut state = ReplayState::new(&cluster, cfg.clone());
    for e in &events {
        state.apply_event(e).expect("apply");
    }
    assert!(
        state.controller().degraded_plans() > 0,
        "plan_deadline 1 should force at least one degraded plan"
    );
    // reproducible and thread-invariant
    for threads in THREAD_MATRIX {
        let mut tcfg = cfg.clone();
        tcfg.threads = threads;
        let rep = replay_trace(&cluster, &trace, &tcfg).expect("degraded replay");
        assert_eq!(
            baseline.fingerprint(),
            rep.fingerprint(),
            "degraded replay differs at {threads} threads"
        );
    }
    // and the degraded decisions recover exactly like healthy ones
    verify_crash_recovery(&cluster, &trace, &cfg, 2, &[], &[])
        .unwrap_or_else(|e| panic!("degraded crash golden: {e}"));
    // the deadline-off path is untouched: plan_deadline 0 reproduces
    // the legacy fingerprint
    let legacy = replay_trace(&cluster, &trace, &fast_cfg(1)).expect("legacy replay");
    let again = replay_trace(&cluster, &trace, &fast_cfg(1)).expect("legacy replay");
    assert_eq!(legacy.fingerprint(), again.fingerprint());
}

// ---------------------------------------------------------------------
// Warm-start cache round trip
// ---------------------------------------------------------------------

/// Extract the solve cache from one replay, warm-start a second replay
/// with it: decisions (fingerprint) are bit-identical, the loaded
/// entries are reported, and the warm run's cache counters start from
/// zero so its hit rate is the true warm hit rate.
#[test]
fn warm_cache_round_trips_through_replay() {
    let cluster = ClusterSpec::two_2080ti();
    let trace = small_trace(2024);
    let cfg = fast_cfg(1);
    let cold = replay_trace(&cluster, &trace, &cfg).expect("cold replay");

    // drive by hand to harvest the final cache contents
    let events = trace_event_list(&trace);
    let mut state = ReplayState::new(&cluster, cfg.clone());
    for e in &events {
        state.apply_event(e).expect("apply");
    }
    let payload = state.cache_json();

    let mut warm_cfg = cfg.clone();
    warm_cfg.warm_cache = Some(payload.clone());
    let warm = replay_trace(&cluster, &trace, &warm_cfg).expect("warm replay");
    assert_eq!(
        cold.fingerprint(),
        warm.fingerprint(),
        "warm-started replay changed decisions"
    );
    // the warm run resolves previously solved requests from the cache
    assert!(
        warm.solve_cache.hits >= cold.solve_cache.hits,
        "warm hits {} < cold hits {}",
        warm.solve_cache.hits,
        cold.solve_cache.hits
    );
    assert!(
        warm.solve_cache.misses <= cold.solve_cache.misses,
        "warm misses {} > cold misses {}",
        warm.solve_cache.misses,
        cold.solve_cache.misses
    );
    // warm_start reports how many entries it seeded
    let probe = ReplayState::new(&cluster, warm_cfg.clone());
    assert!(probe.warm_start().expect("warm start") > 0, "no entries loaded");
    drop(probe);

    // the cells path shares one payload across every cell, and the
    // warm-started sharded replay is bit-identical too
    let cells_cluster = ClusterSpec { num_gpus: 8, ..ClusterSpec::two_2080ti() };
    let cells_trace = small_trace(7);
    let cells_cold = CellsReplayConfig::from_replay(4, &cfg);
    let base = replay_trace_cells(&cells_cluster, &cells_trace, &cells_cold).expect("cells");
    let mut cstate = CellsReplayState::new(&cells_cluster, cells_cold.clone()).expect("state");
    for e in trace_event_list(&cells_trace) {
        cstate.apply_event(&e).expect("apply");
    }
    let cells_payload = cstate.cache_json().expect("merge");
    let mut cells_warm = cells_cold.clone();
    cells_warm.warm_cache = Some(cells_payload);
    let warm = replay_trace_cells(&cells_cluster, &cells_trace, &cells_warm).expect("cells warm");
    assert_eq!(
        base.merged.fingerprint(),
        warm.merged.fingerprint(),
        "warm-started cells replay changed decisions"
    );

    // a malformed payload fails loudly, not silently cold
    let mut bad = cfg.clone();
    bad.warm_cache = Some("{not json".into());
    assert!(replay_trace(&cluster, &trace, &bad).is_err(), "bad payload must error");

    // warm caches compose with durability: the WAL path warm-starts
    // through the same seam and stays bit-identical
    let mut store = MemStore::new();
    let durable_warm = replay_durable(&cluster, &trace, &warm_cfg, &mut store, 2, None)
        .expect("durable warm replay")
        .expect("no crash injected");
    assert_eq!(cold.fingerprint(), durable_warm.fingerprint());
}
