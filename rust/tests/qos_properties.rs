//! QoS property suite over the chaos fuzzer and the admission stack:
//!
//! * a seed-fixed fuzz run over generated chaos scenarios (bursts, GPU
//!   failures, mixed service tiers, diurnal load, cells) is **clean**
//!   — no predicted-QoS audit violations, no re-pack regressions, and
//!   bit-identical replays across 1/2/8 threads — and reproducible;
//! * the `--break-qos` sabotage mode (planner over-committed, QoS
//!   checks disabled) provably produces violations whose dumped
//!   ScenarioSpec JSON re-parses and reproduces the violation — the
//!   invariant-(d) replayability contract;
//! * preemption: a latency-critical arrival a full-of-best-effort
//!   cluster would reject is admitted by evicting best-effort
//!   residents, with the rejection counter untouched;
//! * GPU failure masks capacity (no resident keeps instances on a
//!   failed GPU; nothing new lands there) and recovery restores it.

use camelot::config::ClusterSpec;
use camelot::coordinator::{AdmissionConfig, AdmissionController};
use camelot::planner::ScenarioSpec;
use camelot::suite::fuzz::{check_scenario, generate_spec_json, run_fuzz, FuzzConfig};
use camelot::suite::pipeline_by_name;
use camelot::suite::workload::{ArrivalProcess, Priority};

/// A bounded fuzz run under the production config must be violation-
/// free — invariants (a) QoS audit clean, (b) no re-pack regressions,
/// (c) thread-count determinism — and seed-reproducible.
#[test]
fn fuzz_run_is_clean_and_reproducible() {
    let cfg = FuzzConfig {
        scenarios: 30,
        seed: 7,
        queries: 40,
        ..Default::default()
    };
    let report = run_fuzz(&cfg).expect("fuzz run");
    assert!(
        report.ok(),
        "violations in a production-config fuzz run: {:#?}",
        report
            .violations
            .iter()
            .map(|v| (v.index, &v.kind, &v.detail))
            .collect::<Vec<_>>()
    );
    assert!(report.events_checked > 0, "fuzz run checked no replay events");
    let again = run_fuzz(&cfg).expect("fuzz run");
    assert_eq!(report.events_checked, again.events_checked, "run not reproducible");
}

/// Invariant (d): sabotaged runs dump replayable specs. With the
/// planner over-committed 10× and the admission QoS checks disabled,
/// the audit must catch violations; the dumped JSON must re-parse to
/// the same scenario and reproduce the violation when re-checked.
#[test]
fn break_qos_violations_are_dumped_and_replayable() {
    let dir = std::env::temp_dir().join("camelot-qos-props-breakqos");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = FuzzConfig {
        scenarios: 10,
        seed: 7,
        queries: 40,
        break_qos: true,
        dump_dir: Some(dir.clone()),
        ..Default::default()
    };
    let report = run_fuzz(&cfg).expect("fuzz run");
    let v = report
        .violations
        .iter()
        .find(|v| v.kind == "qos-audit")
        .expect("break-qos sabotage must trip the QoS audit within 10 scenarios");
    // the dump is the exact spec text that was checked
    let path = v.dump_path.as_ref().expect("violation must dump its spec");
    let dumped = std::fs::read_to_string(path).expect("dump readable");
    assert_eq!(dumped, v.spec_json, "dump differs from the checked spec text");
    // ... it re-parses (so `camelot admit --spec <dump>` accepts it) ...
    let spec = ScenarioSpec::parse(&dumped).expect("dump must re-parse");
    assert_eq!(spec.name, format!("fuzz-7-{}", v.index));
    // ... and re-checking it reproduces the violation bit-for-bit
    let problems =
        check_scenario(&dumped, true, false).expect_err("violation must reproduce");
    let (_, detail) =
        problems.iter().find(|(kind, _)| kind == "qos-audit").expect("same invariant");
    assert_eq!(detail, &v.detail, "reproduction differs from the original violation");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A latency-critical arrival the full cluster rejects is admitted by
/// preempting best-effort residents; a successful preemption does not
/// count as a rejection (one arrival, one decision).
#[test]
fn preemption_admits_latency_critical_over_best_effort() {
    let pipeline = pipeline_by_name("text-to-text").expect("pipeline");
    let mut ctl =
        AdmissionController::new(ClusterSpec::two_2080ti(), AdmissionConfig::default());
    // fill the cluster with best-effort residents until one bounces
    let mut admitted = 0;
    for i in 0..20 {
        match ctl.admit_with_priority(
            &format!("be-{i}"),
            &pipeline,
            ArrivalProcess::constant(60.0),
            60.0,
            Priority::BestEffort,
        ) {
            Ok(_) => admitted += 1,
            Err(_) => break,
        }
    }
    assert!(admitted >= 1, "cluster must hold at least one best-effort tenant");
    assert_eq!(ctl.rejected(), 1, "the fill loop ends on the first rejection");
    // plain admission of the same shape still bounces...
    let err = ctl.admit_with_priority(
        "lc",
        &pipeline,
        ArrivalProcess::constant(60.0),
        60.0,
        Priority::LatencyCritical,
    );
    assert!(err.is_err(), "cluster unexpectedly has room: {err:?}");
    let rejected_before = ctl.rejected();
    // ... but preemption clears best-effort room for it: the arrival
    // fits an empty cluster (a best-effort tenant of the same shape
    // was admitted first), so the LC-only feasibility guard passes
    let (id, evicted) = ctl
        .admit_preempting(
            "lc",
            &pipeline,
            ArrivalProcess::constant(60.0),
            60.0,
            Priority::LatencyCritical,
        )
        .expect("preemption must admit the latency-critical arrival");
    assert!(!evicted.is_empty(), "admission without eviction contradicts the plain reject");
    assert!(evicted.iter().all(|name| name.starts_with("be-")), "evicted {evicted:?}");
    assert!(ctl.residents().iter().any(|r| r.id == id));
    assert_eq!(
        ctl.rejected(),
        rejected_before,
        "a successful preemption must not count as a rejection"
    );
    // best-effort arrivals never preempt: a rejected one stays rejected
    let be = ctl.admit_preempting(
        "be-late",
        &pipeline,
        ArrivalProcess::constant(200.0),
        200.0,
        Priority::BestEffort,
    );
    assert!(be.is_err(), "best-effort must not preempt");
}

/// GPU failure semantics: failing a GPU leaves no resident instances
/// on it, admissions while failed avoid it, and recovery clears the
/// mask.
#[test]
fn gpu_failure_masks_capacity_and_recovery_restores_it() {
    let pipeline = pipeline_by_name("img-to-text").expect("pipeline");
    let mut ctl =
        AdmissionController::new(ClusterSpec::two_2080ti(), AdmissionConfig::default());
    ctl.try_admit("a", &pipeline, ArrivalProcess::constant(80.0), 80.0).expect("admit");
    assert!(ctl.failed_gpu_ids().is_empty());

    let report = ctl.fail_gpus(&[0]);
    assert_eq!(report.failed, vec![0]);
    assert_eq!(ctl.failed_gpu_ids(), vec![0]);
    // nobody — displaced-and-replaced or untouched — occupies GPU 0
    for r in ctl.residents() {
        assert!(
            r.deployment.placements.iter().all(|p| p.gpu != 0),
            "resident {} still on failed GPU 0",
            r.name
        );
    }
    // an arrival while failed must land entirely off GPU 0
    if let Ok(id) =
        ctl.try_admit("b", &pipeline, ArrivalProcess::constant(40.0), 40.0)
    {
        let r = ctl.residents().iter().find(|r| r.id == id).expect("resident");
        assert!(r.deployment.placements.iter().all(|p| p.gpu != 0));
    }
    // double-fail is idempotent on the mask
    ctl.fail_gpus(&[0]);
    assert_eq!(ctl.failed_gpu_ids(), vec![0]);

    ctl.recover_gpus(&[0]);
    assert!(ctl.failed_gpu_ids().is_empty(), "recovery must clear the mask");
    // with the whole cluster back, the predicted-QoS audit stays clean
    assert!(ctl.qos_audit().is_empty(), "audit dirty after recovery: {:?}", ctl.qos_audit());
}

/// The generator's traces are canonically ordered (time-ascending), so
/// replay never sees time travel — and burst windows always close.
#[test]
fn generated_traces_are_time_ordered_and_bursts_balanced() {
    use camelot::suite::workload::TraceEventKind;
    for index in 0..20 {
        let json = generate_spec_json(3, index, 40);
        let spec = ScenarioSpec::parse(&json).expect("valid spec");
        let trace = spec.trace();
        let events = if trace.has_bursts() { trace.expanded_events() } else { trace.events.clone() };
        let mut last = f64::NEG_INFINITY;
        let mut open: i64 = 0;
        for e in &events {
            assert!(e.t_s >= last, "scenario {index}: time travel at t={}", e.t_s);
            last = e.t_s;
            match e.kind {
                TraceEventKind::Burst { .. } => open += 1,
                TraceEventKind::BurstEnd => open -= 1,
                _ => {}
            }
        }
        assert_eq!(open, 0, "scenario {index}: unbalanced burst windows");
    }
}
