//! Golden suite for heterogeneity-aware planning (`HeteroPlanner`):
//!
//! * **Identity-class equivalence** — a pool whose `gpu_classes` merely
//!   restate the homogeneous composition (same GPU, `compute_scale`
//!   1.0, continuous partition) replays **bit-identically** to the flat
//!   spec, across 1/2/8 worker threads. This pins the contract that
//!   every heterogeneity code path is gated: homogeneous behavior is
//!   byte-for-byte the pre-hetero behavior.
//! * **Discrete catalogs never over-commit** — on a MIG-sliced pool
//!   every admitted quota lands on the slice grid and no GPU exceeds
//!   its slice budget, even with multiple residents.
//! * **Mixed pools are thread-count invariant** — the determinism
//!   contract extends to pools with a faster class in the mix.

use camelot::config::{ClusterSpec, GpuClass, GpuSpec, PartitionMode, SliceCatalog};
use camelot::coordinator::admission::{replay_trace, ReplayConfig};
use camelot::coordinator::{AdmissionConfig, AdmissionController};
use camelot::suite::workload::{
    ArrivalProcess, Priority, TenantTrace, TenantTraceEvent, TraceEventKind,
};

fn trace3() -> TenantTrace {
    let mk = |t_s: f64, tenant: u64, kind: TraceEventKind| TenantTraceEvent { t_s, tenant, kind };
    let arrive = |pipeline: &str, qps: f64| TraceEventKind::Arrive {
        pipeline: pipeline.into(),
        name: None,
        arrivals: ArrivalProcess::constant(qps),
        plan_qps: qps,
        priority: Priority::LatencyCritical,
    };
    TenantTrace {
        events: vec![
            mk(0.0, 0, arrive("img-to-text", 110.0)),
            mk(40.0, 1, arrive("text-to-text", 70.0)),
            mk(90.0, 2, arrive("img-to-img", 45.0)),
            mk(140.0, 0, TraceEventKind::Shrink { target_qps: 40.0 }),
            mk(220.0, 1, TraceEventKind::Depart),
        ],
    }
}

fn replay_fingerprint(cluster: &ClusterSpec, threads: usize) -> Vec<String> {
    let cfg = ReplayConfig { queries: 240, threads, ..Default::default() };
    replay_trace(cluster, &trace3(), &cfg)
        .expect("replay runs")
        .fingerprint()
}

#[test]
fn identity_classes_reproduce_the_homogeneous_golden_fingerprint() {
    let flat = ClusterSpec { num_gpus: 3, ..ClusterSpec::two_2080ti() };
    let mut tagged = flat.clone();
    tagged.classes = vec![GpuClass::scaled(flat.gpu.clone(), 3, 1.0)];
    tagged.validate_classes().unwrap();
    assert!(tagged.effectively_homogeneous());

    let golden = replay_fingerprint(&flat, 1);
    for threads in [1usize, 2, 8] {
        assert_eq!(
            golden,
            replay_fingerprint(&tagged, threads),
            "identity-class replay drifts from the flat pool at {threads} threads"
        );
        // the flat pool itself must also be thread-count invariant
        assert_eq!(golden, replay_fingerprint(&flat, threads));
    }
}

#[test]
fn discrete_catalog_admissions_never_overcommit_a_gpu() {
    let catalog = SliceCatalog::mig7();
    let units = catalog.units;
    let mut cluster = ClusterSpec { num_gpus: 2, ..ClusterSpec::two_2080ti() };
    cluster.partition = PartitionMode::Discrete(catalog);
    let mut ctl = AdmissionController::new(cluster.clone(), AdmissionConfig::default());
    let mut admitted = 0;
    for (name, pipeline, qps) in [
        ("a", "img-to-text", 90.0),
        ("b", "text-to-text", 60.0),
        ("c", "img-to-img", 40.0),
    ] {
        let p = camelot::suite::pipeline_by_name(pipeline).unwrap();
        if ctl.try_admit(name, &p, ArrivalProcess::constant(qps), qps).is_ok() {
            admitted += 1;
        }
    }
    assert!(admitted >= 2, "a 2-GPU discrete pool should hold at least two tenants");

    let mut per_gpu_units = vec![0u32; cluster.num_gpus];
    for r in ctl.residents() {
        for p in &r.deployment.placements {
            // every quota is a whole number of catalog slices
            let slices = p.sm_frac * units as f64;
            assert!(
                (slices - slices.round()).abs() < 1e-6,
                "{}: quota {} is off the 1/{units} grid",
                r.name,
                p.sm_frac
            );
            per_gpu_units[p.gpu] += slices.round() as u32;
        }
    }
    for (g, &used) in per_gpu_units.iter().enumerate() {
        assert!(used <= units, "GPU {g} over-committed: {used}/{units} slices");
    }
}

#[test]
fn mixed_pool_replay_is_thread_count_invariant() {
    let base = ClusterSpec::two_2080ti();
    let mut mixed = ClusterSpec { num_gpus: 4, ..base.clone() };
    mixed.classes = vec![
        GpuClass::scaled(base.gpu.clone(), 2, 1.0),
        GpuClass::scaled(GpuSpec::a100_sxm4_80g(), 2, 0.7),
    ];
    mixed.validate_classes().unwrap();
    assert!(!mixed.effectively_homogeneous());

    let golden = replay_fingerprint(&mixed, 1);
    assert!(!golden.is_empty());
    for threads in [2usize, 8] {
        assert_eq!(
            golden,
            replay_fingerprint(&mixed, threads),
            "mixed-pool replay differs at {threads} threads"
        );
    }
}
