//! Golden suite for the LLM workload subsystem (KV-cache memory
//! dimension):
//!
//! * **LLM-off is free** — traces without KV-bearing stages replay with
//!   an all-zero KV residency vector and keep their thread-invariant
//!   fingerprints (the memory dimension costs nothing when absent);
//! * **LLM co-location is deterministic** — a mixed LLM + vision trace
//!   replays bit-identically across 1/2/8 worker threads, in the flat
//!   controller and the 4-cell router alike;
//! * **NoMemory is end-to-end** — `examples/scenario_llm_colocate.json`
//!   (the spec `camelot admit --spec` ships) rejects its KV-hungry
//!   tenant with a typed `NoMemory` planner error surfaced in the
//!   decision log, admits the well-shaped LLM tenant, and reports
//!   per-GPU peak KV occupancy bounded by physical memory.

use camelot::config::ClusterSpec;
use camelot::coordinator::admission::{replay_trace, ReplayConfig};
use camelot::coordinator::{replay_trace_cells, CellsConfig, CellsReplayConfig};
use camelot::figures::macro_evals::{admission_tables_for_trace, ReplayKnobs};
use camelot::planner::ScenarioSpec;
use camelot::suite::workload::{TenantTrace, TenantTraceConfig};

fn example_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/scenario_llm_colocate.json")
}

fn replay_cfg(spec: &ScenarioSpec, threads: usize) -> ReplayConfig {
    let mut cfg = ReplayConfig {
        queries: spec.queries,
        threads,
        ..Default::default()
    };
    cfg.admission.seed = spec.seed;
    cfg.admission.batch = spec.batch;
    cfg
}

/// A mixed LLM + vision co-location scenario on an 8-GPU pool, sized
/// so the replay stays brisk across the full thread matrix.
fn colocate_spec() -> ScenarioSpec {
    ScenarioSpec::parse(
        r#"{
        "name": "llm-colocate-golden",
        "cluster": {"preset": "2080ti", "gpus": 8},
        "batch": 16,
        "seed": 11,
        "queries": 160,
        "tenants": [
            {"name": "chat", "workload": "llm", "plan_qps": 8.0,
             "arrivals": "constant", "arrive_s": 0.0},
            {"name": "search", "pipeline": "img-to-text", "plan_qps": 40.0,
             "arrivals": "diurnal", "arrive_s": 5.0, "depart_s": 600.0},
            {"name": "chat-batch", "workload": "llm", "plan_qps": 6.0,
             "prompt_tokens": 256, "output_tokens": 64,
             "kv_bytes_per_token": 131072,
             "arrivals": "constant", "arrive_s": 10.0}
        ]
    }"#,
    )
    .expect("golden spec parses")
}

#[test]
fn llm_off_replay_has_zero_kv_and_stays_thread_invariant() {
    let cluster = ClusterSpec::two_2080ti();
    let trace = TenantTrace::generate(
        &TenantTraceConfig {
            tenants: 5,
            mean_interarrival_s: 300.0,
            mean_lifetime_s: 900.0,
            peak_qps_lo: 40.0,
            peak_qps_hi: 110.0,
            ..Default::default()
        },
        2024,
    );
    let cfg = |threads| ReplayConfig { queries: 120, threads, ..Default::default() };
    let baseline = replay_trace(&cluster, &trace, &cfg(1)).expect("flat replay");
    // no KV-bearing stage anywhere: the memory dimension must be inert
    assert_eq!(baseline.kv_peak_bytes.len(), cluster.num_gpus);
    assert!(
        baseline.kv_peak_bytes.iter().all(|&b| b == 0.0),
        "legacy trace accrued KV residency: {:?}",
        baseline.kv_peak_bytes
    );
    for threads in [2usize, 8] {
        let rep = replay_trace(&cluster, &trace, &cfg(threads)).expect("flat replay");
        assert_eq!(
            baseline.fingerprint(),
            rep.fingerprint(),
            "legacy replay differs at {threads} threads"
        );
        assert!(rep.kv_peak_bytes.iter().all(|&b| b == 0.0));
    }
}

#[test]
fn llm_colocation_flat_replay_is_thread_invariant() {
    let spec = colocate_spec();
    let trace = spec.trace();
    let baseline =
        replay_trace(&spec.cluster, &trace, &replay_cfg(&spec, 1)).expect("flat replay");
    assert!(baseline.admitted >= 2, "co-location trace must admit: {baseline:?}");
    // an admitted LLM tenant leaves a real KV footprint, bounded by HBM
    let peak = baseline.kv_peak_bytes.iter().cloned().fold(0.0f64, f64::max);
    assert!(peak > 0.0, "no KV residency recorded: {:?}", baseline.kv_peak_bytes);
    for (g, &b) in baseline.kv_peak_bytes.iter().enumerate() {
        assert!(
            b <= spec.cluster.gpu_at(g).mem_bytes as f64,
            "gpu {g} KV peak {b} exceeds physical memory"
        );
    }
    for threads in [2usize, 8] {
        let rep = replay_trace(&spec.cluster, &trace, &replay_cfg(&spec, threads))
            .expect("flat replay");
        assert_eq!(
            baseline.fingerprint(),
            rep.fingerprint(),
            "LLM co-location replay differs at {threads} threads"
        );
        for (a, b) in baseline.kv_peak_bytes.iter().zip(&rep.kv_peak_bytes) {
            assert_eq!(a.to_bits(), b.to_bits(), "KV peaks drift at {threads} threads");
        }
    }
}

#[test]
fn llm_colocation_cells_replay_is_thread_invariant() {
    let spec = colocate_spec();
    let trace = spec.trace();
    let cfg = |threads| CellsReplayConfig {
        router: CellsConfig { cells: 4, ..Default::default() },
        queries: spec.queries,
        threads,
        dedup: true,
        audit_qos: false,
        ..Default::default()
    };
    let baseline =
        replay_trace_cells(&spec.cluster, &trace, &cfg(1)).expect("cells replay");
    assert!(baseline.merged.admitted >= 2);
    assert!(
        baseline.merged.kv_peak_bytes.iter().any(|&b| b > 0.0),
        "no KV residency in the 4-cell replay: {:?}",
        baseline.merged.kv_peak_bytes
    );
    for threads in [2usize, 8] {
        let rep =
            replay_trace_cells(&spec.cluster, &trace, &cfg(threads)).expect("cells replay");
        assert_eq!(
            baseline.merged.fingerprint(),
            rep.merged.fingerprint(),
            "4-cell LLM replay differs at {threads} threads"
        );
        assert_eq!(baseline.tenant_cells, rep.tenant_cells);
        for (a, b) in baseline
            .merged
            .kv_peak_bytes
            .iter()
            .zip(&rep.merged.kv_peak_bytes)
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn example_spec_rejects_kv_hungry_tenant_with_no_memory() {
    let spec = ScenarioSpec::load(&example_path()).expect("example parses");
    let trace = spec.trace();
    let rep = replay_trace(&spec.cluster, &trace, &replay_cfg(&spec, 1)).expect("replay");
    // the KV-hungry tenant is rejected with the typed planner error...
    assert!(rep.rejected >= 1, "example must reject: {:?}", rep.events);
    assert!(
        rep.events
            .iter()
            .any(|e| e.decision.contains("NoMemory")),
        "no NoMemory rejection in the decision log: {:?}",
        rep.events
            .iter()
            .map(|e| (&e.desc, &e.decision))
            .collect::<Vec<_>>()
    );
    // ...while the well-shaped LLM tenant is admitted and measured
    assert!(rep.admitted >= 1);
    assert!(
        rep.kv_peak_bytes.iter().any(|&b| b > 0.0),
        "admitted LLM tenant left no KV footprint: {:?}",
        rep.kv_peak_bytes
    );
}

#[test]
fn example_spec_emits_the_kv_occupancy_table() {
    // the exact path `camelot admit --spec` takes
    let spec = ScenarioSpec::load(&example_path()).expect("example parses");
    let knobs = ReplayKnobs {
        queries: spec.queries,
        batch: spec.batch,
        seed: spec.seed,
        cells: spec.cells,
        break_qos: false,
    };
    let tables = admission_tables_for_trace(&spec.cluster, &spec.trace(), knobs)
        .expect("admission tables");
    let kv_table = tables
        .iter()
        .find(|t| t.title.contains("KV-cache residency"))
        .expect("per-GPU peak KV occupancy table missing");
    assert_eq!(kv_table.rows.len(), spec.cluster.num_gpus);
    assert!(
        kv_table.rows.iter().any(|r| r[2] != "0.000"),
        "KV table is all-zero: {kv_table:?}"
    );
}
