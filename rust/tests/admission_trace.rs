//! Golden/determinism suite for the N-tenant admission controller:
//!
//! * replaying the same `TenantTrace` with 1, 2, and 8 threads yields
//!   bit-identical admission decisions, re-pack plans, and per-tenant
//!   p99s (phase-1 decisions are sequential by construction; phase-2
//!   interval simulations land by input index);
//! * a degenerate single-tenant constant-rate trace reproduces
//!   `Simulator::run` bit-for-bit (interval 0 seeds from the base seed
//!   exactly, and `ClusterSim` degenerates to the single-tenant
//!   engine).

use camelot::config::ClusterSpec;
use camelot::coordinator::admission::{replay_trace, AdmissionController, ReplayConfig};
use camelot::coordinator::AdmissionConfig;
use camelot::sim::{SimOptions, Simulator};
use camelot::suite::workload::{
    ArrivalProcess, Priority, TenantTrace, TenantTraceConfig, TenantTraceEvent, TraceEventKind,
};

/// Everything a replay decides or measures, flattened to exact bits.
fn fingerprint(rep: &camelot::coordinator::ReplayReport) -> Vec<String> {
    let mut out = Vec::new();
    for e in &rep.events {
        out.push(format!(
            "event t={} tenant={} {} -> {} residents={} gpus={} usage={}",
            e.t_s.to_bits(),
            e.tenant,
            e.desc,
            e.decision,
            e.residents,
            e.gpus_in_use,
            e.usage.to_bits()
        ));
    }
    for iv in &rep.intervals {
        out.push(format!(
            "interval t={} tenants={:?} p99={:?} qos={:?}",
            iv.t_start_s.to_bits(),
            iv.tenants,
            iv.p99_s.iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
            iv.qos_met
        ));
    }
    out.push(format!(
        "summary admitted={} rejected={} repacks={} peak={} mean_gpus={}",
        rep.admitted,
        rep.rejected,
        rep.repacks_applied,
        rep.peak_residents,
        rep.mean_gpus_in_use.to_bits()
    ));
    out
}

#[test]
fn trace_replay_identical_across_thread_counts() {
    let cluster = ClusterSpec::two_2080ti();
    let trace = TenantTrace::generate(
        &TenantTraceConfig {
            tenants: 6,
            mean_interarrival_s: 300.0,
            mean_lifetime_s: 900.0,
            peak_qps_lo: 40.0,
            peak_qps_hi: 110.0,
            ..Default::default()
        },
        2024,
    );
    let replay = |threads: usize| {
        let cfg = ReplayConfig { queries: 400, threads, ..Default::default() };
        fingerprint(&replay_trace(&cluster, &trace, &cfg).expect("replay runs"))
    };
    let serial = replay(1);
    // the trace must exercise the interesting paths, or this test
    // proves nothing: admissions, at least one departure, intervals
    assert!(serial.iter().any(|l| l.contains("-> admitted")));
    assert!(serial.iter().any(|l| l.contains("repack:")));
    assert!(serial.iter().any(|l| l.starts_with("interval")));
    for threads in [2usize, 8] {
        assert_eq!(
            serial,
            replay(threads),
            "replay differs at {threads} threads"
        );
    }
}

#[test]
fn degenerate_single_tenant_trace_matches_simulator_run() {
    let cluster = ClusterSpec::two_2080ti();
    let rate = 90.0;
    let queries = 800;
    // a one-tenant trace: constant-rate arrivals, never departs
    let trace = TenantTrace {
        events: vec![TenantTraceEvent {
            t_s: 0.0,
            tenant: 0,
            kind: TraceEventKind::Arrive {
                pipeline: "img-to-text".into(),
                name: None,
                arrivals: ArrivalProcess::constant(rate),
                plan_qps: rate,
                priority: Priority::LatencyCritical,
            },
        }],
    };
    let cfg = ReplayConfig { queries, threads: 1, ..Default::default() };
    let rep = replay_trace(&cluster, &trace, &cfg).expect("replay runs");
    assert_eq!(rep.admitted, 1);
    assert_eq!(rep.intervals.len(), 1);
    assert_eq!(rep.intervals[0].p99_s.len(), 1);

    // the controller plans deterministically: run the same admission
    // standalone to recover the deployment, then drive the
    // single-tenant engine directly — interval 0 mixes the base seed
    // with index 0, which is the base seed itself
    let p = camelot::suite::pipeline_by_name("img-to-text").unwrap();
    let mut ctl = AdmissionController::new(cluster.clone(), AdmissionConfig::default());
    ctl.try_admit("img-to-text#0", &p, ArrivalProcess::constant(rate), rate)
        .expect("standalone admission matches the replay's");
    assert_eq!(ctl.residents().len(), 1);
    let d = ctl.residents()[0].deployment.clone();
    let opts = SimOptions {
        seed: cfg.admission.seed,
        queries,
        ..Default::default()
    };
    let single = Simulator::new(&p, &cluster, &d, opts).run(rate).unwrap();
    assert_eq!(
        rep.intervals[0].p99_s[0].to_bits(),
        single.p99().to_bits(),
        "degenerate replay p99 {} vs engine {}",
        rep.intervals[0].p99_s[0],
        single.p99()
    );
    assert_eq!(
        rep.intervals[0].qos_met[0],
        single.p99() <= p.qos_target_s
    );
}

#[test]
fn controller_decision_sequence_reproducible() {
    // two controllers fed the same arrivals make bit-identical plans —
    // the property replay determinism rests on
    let cluster = ClusterSpec::two_2080ti();
    let p1 = camelot::suite::pipeline_by_name("img-to-text").unwrap();
    let p2 = camelot::suite::pipeline_by_name("text-to-text").unwrap();
    let drive = |ctl: &mut AdmissionController| -> Vec<String> {
        let mut log = Vec::new();
        for (name, p, qps) in [
            ("a", &p1, 120.0),
            ("b", &p2, 80.0),
            ("c", &p1, 150.0),
            ("d", &p2, 60.0),
        ] {
            match ctl.try_admit(name, p, ArrivalProcess::constant(qps), qps) {
                Ok(id) => {
                    let r = ctl
                        .residents()
                        .iter()
                        .find(|r| r.id == id)
                        .unwrap();
                    log.push(format!(
                        "{name}: admitted {:?} {:?} gpus={}",
                        r.allocation.instances,
                        r.deployment.placements,
                        ctl.gpus_in_use()
                    ));
                }
                Err(e) => log.push(format!("{name}: {e}")),
            }
        }
        log
    };
    let mut ca = AdmissionController::new(cluster.clone(), AdmissionConfig::default());
    let mut cb = AdmissionController::new(cluster, AdmissionConfig::default());
    assert_eq!(drive(&mut ca), drive(&mut cb));
}
