"""AOT exporter: lower every (stage, batch) variant to HLO text.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` 0.1.6 crate) rejects (`proto.id() <=
INT_MAX`). The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
    <stage>_b<batch>.hlo.txt   one per variant
    manifest.json              metadata the Rust runtime + simulator read:
                               shapes, FLOPs, parameter bytes, stage kind

Usage: cd python && python -m compile.aot [--out-dir ../artifacts]
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from compile.model import DEFAULT_BATCHES, STAGES, artifact_name, build_stage


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_variant(spec, batch: int, out_dir: pathlib.Path) -> dict:
    """Lower one (stage, batch) variant; return its manifest entry."""
    fwd, example_args = build_stage(spec, batch)
    lowered = jax.jit(fwd).lower(*example_args)
    text = to_hlo_text(lowered)
    name = artifact_name(spec.name, batch)
    path = out_dir / f"{name}.hlo.txt"
    path.write_text(text)
    return {
        "name": name,
        "stage": spec.name,
        "kind": spec.kind,
        "batch": batch,
        "input_shape": [batch, spec.d_in],
        "output_shape": [batch, spec.d_out],
        "flops": spec.flops_per_query(batch),
        "param_bytes": spec.param_bytes(),
        "activation_bytes_in": 4 * batch * spec.d_in,
        "activation_bytes_out": 4 * batch * spec.d_out,
        "file": path.name,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="compat shim: also write the first artifact here")
    ap.add_argument("--stages", nargs="*", default=None,
                    help="subset of stage names (default: all)")
    ap.add_argument("--batches", nargs="*", type=int, default=None)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    stages = args.stages or list(STAGES)
    batches = tuple(args.batches) if args.batches else DEFAULT_BATCHES

    manifest = []
    for stage in stages:
        spec = STAGES[stage]
        for batch in batches:
            entry = export_variant(spec, batch, out_dir)
            manifest.append(entry)
            print(f"  wrote {entry['file']:36s} "
                  f"flops/query={entry['flops']:.3e}")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {len(manifest)} artifacts + manifest.json to {out_dir}")

    if args.out:  # Makefile sentinel target
        sentinel = pathlib.Path(args.out)
        sentinel.parent.mkdir(parents=True, exist_ok=True)
        first = out_dir / manifest[0]["file"]
        sentinel.write_text(first.read_text())


if __name__ == "__main__":
    main()
