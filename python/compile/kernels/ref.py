"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(python/tests/) asserts allclose between the two across a hypothesis
sweep of shapes and dtypes. This is the core L1 correctness signal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _apply_activation(x, activation: str):
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "tanh":
        return jnp.tanh(x)
    if activation == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {activation!r}")


def matmul_bias_act(x, w, b, *, activation: str = "none"):
    """Oracle for kernels.matmul.matmul_bias_act."""
    out = jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) + b.astype(jnp.float32)
    return _apply_activation(out, activation).astype(x.dtype)


def stream_scale_add(x, y, scale: float = 0.5, *, passes: int = 1):
    """Oracle for kernels.stream.stream_scale_add."""
    acc = y
    for _ in range(passes):
        acc = acc * scale + x
    return acc
