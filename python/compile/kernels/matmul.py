"""L1 Pallas kernel: tiled matmul + bias + activation.

This is the compute hot-spot of every DNN microservice stage in the
Camelot suite (the VGG / BERT / LSTM / DC-GAN proxies are all stacks of
dense matmuls). The paper's CUDA kernels tile for shared memory and
threadblocks; on TPU-shaped hardware the same insight becomes a BlockSpec
schedule: the grid iterates over (M/bm, N/bn) output tiles, a K-loop
streams (bm, bk) x (bk, bn) operand tiles HBM->VMEM, and a VMEM scratch
accumulator feeds the MXU with aligned tiles. See DESIGN.md
SS3 Hardware-Adaptation.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated against kernels/ref.py and real-TPU
performance is *estimated* from the VMEM footprint / MXU-utilization model
in `vmem_report` (used by EXPERIMENTS.md SSPerf).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Activation = Literal["none", "relu", "gelu", "tanh", "sigmoid"]

# Default block shapes: MXU-aligned (128x128 systolic array), three
# f32 operand tiles + one accumulator comfortably inside ~16 MiB VMEM.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _apply_activation(x, activation: Activation):
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "tanh":
        return jnp.tanh(x)
    if activation == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {activation!r}")


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nsteps_k: int,
                   activation: Activation):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk).

    The K dimension is the innermost grid axis, so `acc_ref` (VMEM
    scratch) accumulates partial products across the K steps and the
    epilogue (bias + activation) fires on the last step only.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU-shaped partial product; accumulate in f32 regardless of the
    # input dtype so low-precision inputs do not lose the K reduction.
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nsteps_k - 1)
    def _epilogue():
        acc = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_activation(acc, activation).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "bm", "bn", "bk", "interpret"),
)
def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: Activation = "none",
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Compute ``act(x @ w + b)`` with a tiled Pallas kernel.

    Shapes: x (M, K), w (K, N), b (N,) -> (M, N). M, K, N need not be
    multiples of the block shape; blocks are clamped to the array bounds.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: x {x.shape} vs w {w.shape}")
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")

    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)

    # Interpret mode fills out-of-bounds block elements with NaN; zero-pad
    # ragged dimensions up front (zeros are the identity for the K
    # reduction) and slice the result back down afterwards.
    mp, kp, np_ = (pl.cdiv(m, bm_) * bm_, pl.cdiv(k, bk_) * bk_,
                   pl.cdiv(n, bn_) * bn_)
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    if np_ != n:
        b = jnp.pad(b, (0, np_ - n))
    grid = (mp // bm_, np_ // bn_, kp // bk_)

    out = pl.pallas_call(
        functools.partial(
            _matmul_kernel, nsteps_k=grid[2], activation=activation
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn_), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[_vmem_scratch(bm_, bn_)],
        interpret=interpret,
    )(x, w, b.reshape(1, np_))
    return out[:m, :n]


def _vmem_scratch(bm: int, bn: int):
    """VMEM f32 scratch allocation (TPU spelling; interpret honors it)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((bm, bn), jnp.float32)


def vmem_report(m: int, k: int, n: int, *, bm: int = DEFAULT_BM,
                bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                dtype_bytes: int = 4) -> dict:
    """Static VMEM-footprint + MXU-utilization estimate for a block shape.

    Used by the SSPerf pass: interpret-mode wallclock is meaningless for
    TPU, so we reason about the structure — how much VMEM a grid step
    touches, and how well the tile shapes fill the 128x128 MXU.
    """
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    vmem = (bm_ * bk_ + bk_ * bn_ + bn_) * dtype_bytes + bm_ * bn_ * 4 * 2
    mxu = 128
    util = (
        (min(bm_, mxu) / mxu)
        * (min(bn_, mxu) / mxu)
        * (min(bk_, mxu) / mxu)
    )
    flops = 2.0 * m * n * k
    hbm_traffic = (
        # each output tile streams K/bk operand tile pairs
        pl.cdiv(m, bm_) * pl.cdiv(n, bn_) * pl.cdiv(k, bk_)
        * (bm_ * bk_ + bk_ * bn_) * dtype_bytes
        + m * n * dtype_bytes
    )
    return {
        "block": (bm_, bn_, bk_),
        "grid": (pl.cdiv(m, bm_), pl.cdiv(n, bn_), pl.cdiv(k, bk_)),
        "vmem_bytes": int(vmem),
        "mxu_tile_utilization": float(util),
        "flops": flops,
        "hbm_bytes": float(hbm_traffic),
        "arithmetic_intensity": flops / hbm_traffic,
    }
