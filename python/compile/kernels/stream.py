"""L1 Pallas kernel: blocked streaming update (memory-bound).

Models the *memory-intensive* artifact microservice of the Camelot suite
(ported from the Rodinia streaming workloads in the paper): for each
element it performs `passes` fused multiply-adds per byte moved, so the
arithmetic intensity is configurable — exactly the knob the paper's
artifact benchmarks m1..m3 / c1..c3 expose (Fig 3).

The BlockSpec splits the vector into VMEM-sized chunks; each grid step
streams one chunk HBM->VMEM, applies the update, and writes it back —
the TPU rendering of a bandwidth-bound CUDA grid-stride loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _stream_kernel(x_ref, y_ref, o_ref, *, passes: int, scale: float):
    x = x_ref[...]
    y = y_ref[...]
    acc = y
    # `passes` controls FLOPs per byte: c1..c3 raise it, m1..m3 keep it
    # at 1 so the kernel stays bandwidth-bound.
    for _ in range(passes):
        acc = acc * scale + x
    o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("scale", "passes", "block", "interpret")
)
def stream_scale_add(
    x: jax.Array,
    y: jax.Array,
    scale: float = 0.5,
    *,
    passes: int = 1,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """Blocked ``y*scale + x`` applied ``passes`` times (triad-like)."""
    if x.shape != y.shape:
        raise ValueError(f"shape mismatch {x.shape} vs {y.shape}")
    if x.ndim != 1:
        raise ValueError("stream kernel takes 1-D operands")
    n = x.shape[0]
    blk = min(block, n)
    # Interpret mode fills out-of-bounds block elements with NaN; pad the
    # ragged tail explicitly and slice it back off.
    np_ = pl.cdiv(n, blk) * blk
    if np_ != n:
        x = jnp.pad(x, (0, np_ - n))
        y = jnp.pad(y, (0, np_ - n))
    grid = (np_ // blk,)
    out = pl.pallas_call(
        functools.partial(_stream_kernel, passes=passes, scale=float(scale)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), x.dtype),
        interpret=interpret,
    )(x, y)
    return out[:n]
