"""L1 performance pass: BlockSpec sweep for the Pallas matmul kernel.

interpret=True wallclock is CPU-numpy time, NOT a TPU proxy — so this
tool optimizes *structure*: for each candidate (bm, bn, bk) it reports
the static VMEM footprint, the MXU tile utilization, the HBM traffic,
and the arithmetic intensity from `matmul.vmem_report`, then verifies
numerics of the winning shape against ref.py. The chosen shape is what
`matmul_bias_act` ships as its default; EXPERIMENTS.md §Perf records the
sweep.

Usage: cd python && python -m compile.perf_sweep [M K N]
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import ref
from compile.kernels.matmul import matmul_bias_act, vmem_report

VMEM_BUDGET = 16 * 2**20  # ~16 MiB per TPU core

CANDIDATES = [
    (64, 64, 64),
    (128, 128, 64),
    (128, 128, 128),
    (128, 128, 256),
    (128, 256, 128),
    (256, 128, 128),
    (256, 256, 128),
    (256, 256, 256),
    (512, 128, 128),
    (128, 512, 128),
]


def score(rep: dict) -> float:
    """Structure score: maximize MXU utilization and arithmetic
    intensity subject to the VMEM budget."""
    if rep["vmem_bytes"] > VMEM_BUDGET:
        return -1.0
    return rep["mxu_tile_utilization"] * rep["arithmetic_intensity"]


def main() -> None:
    args = [int(a) for a in sys.argv[1:4]] or [512, 1024, 512]
    m, k, n = (args + [512, 1024, 512])[:3]
    print(f"matmul block-shape sweep for M={m} K={k} N={n}")
    print(f"{'bm':>4} {'bn':>4} {'bk':>4} {'vmem_KiB':>9} {'mxu_util':>9} "
          f"{'AI':>8} {'hbm_MB':>8} {'score':>8}")
    best = None
    for bm, bn, bk in CANDIDATES:
        rep = vmem_report(m, k, n, bm=bm, bn=bn, bk=bk)
        s = score(rep)
        print(f"{bm:>4} {bn:>4} {bk:>4} {rep['vmem_bytes'] / 1024:>9.0f} "
              f"{rep['mxu_tile_utilization']:>9.2f} "
              f"{rep['arithmetic_intensity']:>8.1f} "
              f"{rep['hbm_bytes'] / 1e6:>8.1f} {s:>8.1f}"
              + ("  (over VMEM budget)" if s < 0 else ""))
        if best is None or s > best[1]:
            best = ((bm, bn, bk), s)
    (bm, bn, bk), s = best
    print(f"\nbest structure: bm={bm} bn={bn} bk={bk} (score {s:.1f})")

    # correctness of the winning shape
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32)
    got = matmul_bias_act(x, w, b, activation="gelu", bm=bm, bn=bn, bk=bk)
    exp = ref.matmul_bias_act(x, w, b, activation="gelu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-4, atol=2e-4)
    print("numerics of winning shape: OK (allclose vs ref)")


if __name__ == "__main__":
    main()
