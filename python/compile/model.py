"""L2: JAX microservice stage models for the Camelot suite.

Each *microservice stage* of the paper's pipelines (Table I) is a JAX
forward function built on the L1 Pallas kernels; `aot.py` lowers each
(stage, batch) variant ONCE to HLO text, and the Rust coordinator serves
them via PJRT with Python never on the request path.

Stage proxies and the paper stage they stand in for:

| proxy         | paper stages                           | signature        |
|---------------|----------------------------------------|------------------|
| mlp_stage     | BERT summarize / VGG feature extract / | compute-bound,   |
|               | FSRCNN enhance / DC-GAN generate       | matmul stack     |
| lstm_stage    | LSTM caption / semantic understanding /| sequential scan  |
|               | OpenNMT translate                      | of cell matmuls  |
| stream_stage  | memory-intensive artifact microservice | bandwidth-bound  |

Every stage takes a (batch, feature) activation and returns the next
stage's (batch, feature) activation, so arbitrary pipelines compose.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from compile.kernels.matmul import matmul_bias_act
from compile.kernels.stream import stream_scale_add


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """Static description of one microservice stage variant.

    `name` keys the AOT artifact; the remaining fields size the graph.
    """

    name: str
    kind: str  # "mlp" | "lstm" | "stream"
    d_in: int
    d_hidden: int
    d_out: int
    depth: int = 2  # mlp: #layers; lstm: #time steps; stream: #passes

    def param_shapes(self) -> list[tuple[int, ...]]:
        """Shapes of the weights, in the order the stage fn consumes them."""
        if self.kind == "mlp":
            shapes: list[tuple[int, ...]] = []
            dims = [self.d_in] + [self.d_hidden] * (self.depth - 1) + [self.d_out]
            for a, b in zip(dims[:-1], dims[1:]):
                shapes += [(a, b), (b,)]
            return shapes
        if self.kind == "lstm":
            # fused gate weights: x-proj, h-proj, bias; plus output head
            return [
                (self.d_in, 4 * self.d_hidden),
                (self.d_hidden, 4 * self.d_hidden),
                (4 * self.d_hidden,),
                (self.d_hidden, self.d_out),
                (self.d_out,),
            ]
        if self.kind == "stream":
            return [(min(self.d_in, 4096),)]
        raise ValueError(f"unknown stage kind {self.kind!r}")

    def init_params(self, key: jax.Array) -> list[jax.Array]:
        """He-ish random init, deterministic per key."""
        params = []
        for shape in self.param_shapes():
            key, sub = jax.random.split(key)
            fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
            params.append(
                jax.random.normal(sub, shape, jnp.float32)
                / jnp.sqrt(jnp.float32(fan_in))
            )
        return params

    def param_bytes(self) -> int:
        """Weight footprint (f32) — the model-sharing term of M(i, s)."""
        total = 0
        for shape in self.param_shapes():
            n = 1
            for d in shape:
                n *= d
            total += 4 * n
        return total

    def flops_per_query(self, batch: int) -> float:
        """Analytical FLOPs — feeds the simulator's calibration (C(i,s))."""
        if self.kind == "mlp":
            dims = [self.d_in] + [self.d_hidden] * (self.depth - 1) + [self.d_out]
            return float(sum(2 * batch * a * b for a, b in zip(dims[:-1], dims[1:])))
        if self.kind == "lstm":
            per_step = 2 * batch * (self.d_in + self.d_hidden) * 4 * self.d_hidden
            head = 2 * batch * self.d_hidden * self.d_out
            return float(self.depth * per_step + head)
        if self.kind == "stream":
            return float(2 * batch * self.d_in * self.depth)
        raise ValueError(self.kind)


def mlp_stage(params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """Stack of Pallas matmul+bias+gelu layers (compute-bound proxy)."""
    h = x
    n_layers = len(params) // 2
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        act = "gelu" if i < n_layers - 1 else "none"
        h = matmul_bias_act(h, w, b, activation=act)
    return h


def lstm_stage(params: Sequence[jax.Array], x: jax.Array, *, steps: int) -> jax.Array:
    """LSTM cell scanned over `steps` virtual tokens, then a dense head.

    The same (batch, d_in) activation is fed at each step — the pipeline
    carries activations, not token streams — so the stage is a faithful
    *cost* proxy for the caption/translate microservices while staying a
    pure (batch, d_in) -> (batch, d_out) function. `lax.scan` keeps the
    lowered HLO compact (one While op) versus `depth`-way unrolling.
    """
    wx, wh, b, w_head, b_head = params
    hidden = wh.shape[0]
    h0 = jnp.zeros((x.shape[0], hidden), x.dtype)
    c0 = jnp.zeros((x.shape[0], hidden), x.dtype)
    # The input projection does not depend on the carry: hoist it out of
    # the scan so it is computed once, not `steps` times.
    x_proj = matmul_bias_act(x, wx, b)

    def cell(carry, _):
        h, c = carry
        gates = x_proj + matmul_bias_act(h, wh, jnp.zeros_like(b))
        i, f, g, o = jnp.split(gates, 4, axis=1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    (h, _), _ = jax.lax.scan(cell, (h0, c0), None, length=steps)
    return matmul_bias_act(h, w_head, b_head)


def stream_stage(params: Sequence[jax.Array], x: jax.Array, *, passes: int) -> jax.Array:
    """Bandwidth-bound proxy: blocked stream update over the activations."""
    (scale_vec,) = params
    flat = x.reshape(-1)
    reps = -(-flat.shape[0] // scale_vec.shape[0])  # ceil division
    other = jnp.tile(scale_vec, reps)[: flat.shape[0]]
    out = stream_scale_add(flat, other, scale=0.5, passes=passes)
    return out.reshape(x.shape)


def stage_fn(spec: StageSpec):
    """Return the (params, x) -> y forward function for a StageSpec."""
    if spec.kind == "mlp":
        return mlp_stage
    if spec.kind == "lstm":
        return functools.partial(lstm_stage, steps=spec.depth)
    if spec.kind == "stream":
        return functools.partial(stream_stage, passes=spec.depth)
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# The artifact catalogue: stage variants the Rust runtime loads by name.
# Sizes are chosen so that solo-run PJRT-CPU latencies sit in the
# single-to-tens-of-milliseconds range at batch 8-64, matching the paper's
# per-stage budgets relative to its QoS targets.
# ---------------------------------------------------------------------------

STAGES: dict[str, StageSpec] = {
    # img-to-text proxy: VGG-ish feature extractor -> LSTM caption head
    "vgg_features": StageSpec("vgg_features", "mlp", 512, 1024, 512, depth=4),
    "lstm_caption": StageSpec("lstm_caption", "lstm", 512, 256, 512, depth=8),
    # text-to-text proxy: BERT-ish summarizer -> NMT decoder
    "bert_summarize": StageSpec("bert_summarize", "mlp", 768, 768, 768, depth=6),
    "nmt_translate": StageSpec("nmt_translate", "lstm", 768, 384, 768, depth=6),
    # img-to-img proxy: face recognition -> FSRCNN enhancement
    "face_recognition": StageSpec("face_recognition", "mlp", 512, 512, 256, depth=5),
    "fsrcnn_enhance": StageSpec("fsrcnn_enhance", "mlp", 256, 512, 512, depth=3),
    # text-to-img proxy: LSTM semantic understanding -> DC-GAN generator
    "lstm_semantic": StageSpec("lstm_semantic", "lstm", 384, 256, 384, depth=6),
    "dcgan_generate": StageSpec("dcgan_generate", "mlp", 384, 1024, 768, depth=4),
    # artifact microservices (Fig 3 / SSVIII-E): tunable intensity
    "artifact_compute": StageSpec("artifact_compute", "mlp", 512, 1536, 512, depth=4),
    "artifact_memory": StageSpec("artifact_memory", "stream", 1 << 16, 0, 1 << 16, depth=2),
}

DEFAULT_BATCHES = (8, 16, 32, 64)


def artifact_name(stage: str, batch: int) -> str:
    """Artifact file stem for a (stage, batch) variant."""
    return f"{stage}_b{batch}"


def build_stage(spec: StageSpec, batch: int):
    """(jitted fn, example args) pair for AOT lowering of one variant.

    Weights are baked into the artifact as constants (closure capture):
    the serving path then takes a single (batch, d_in) activation input,
    which is exactly what the Rust coordinator feeds it.
    """
    params = spec.init_params(jax.random.PRNGKey(hash(spec.name) % (1 << 31)))
    fn = stage_fn(spec)

    def fwd(x):
        return (fn(params, x),)

    example = jax.ShapeDtypeStruct((batch, spec.d_in), jnp.float32)
    return fwd, (example,)
