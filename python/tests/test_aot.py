"""AOT exporter tests: HLO text well-formedness and manifest integrity.

These validate the L2→L3 interchange contract without requiring the
Rust side: the HLO text must parse-able by XLA's text parser (we check
the structural markers the Rust loader relies on) and the manifest must
describe every artifact accurately.
"""

import json
import pathlib

import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    """Export a small subset once for the module."""
    out = tmp_path_factory.mktemp("artifacts")
    entries = []
    for stage in ["fsrcnn_enhance", "artifact_memory"]:
        spec = model.STAGES[stage]
        entries.append(aot.export_variant(spec, 8, out))
    (out / "manifest.json").write_text(json.dumps(entries, indent=2))
    return out, entries


def test_hlo_text_structure(exported):
    out, entries = exported
    for e in entries:
        text = (out / e["file"]).read_text()
        assert text.startswith("HloModule"), e["file"]
        assert "ENTRY" in text
        # tuple return (the Rust side unwraps to_tuple1)
        assert "ROOT" in text


def test_manifest_shapes_match_model(exported):
    _, entries = exported
    for e in entries:
        spec = model.STAGES[e["stage"]]
        assert e["input_shape"] == [8, spec.d_in]
        assert e["output_shape"] == [8, spec.d_out]
        assert e["flops"] == spec.flops_per_query(8)
        assert e["param_bytes"] == spec.param_bytes()


def test_exported_fn_runs_and_matches_jit(exported):
    """The lowered computation must agree with direct jit execution."""
    import numpy as np

    for stage in ["fsrcnn_enhance"]:
        spec = model.STAGES[stage]
        fwd, (example,) = model.build_stage(spec, 8)
        x = jax.random.normal(jax.random.PRNGKey(3), example.shape, example.dtype)
        direct = fwd(x)[0]
        jitted = jax.jit(fwd)(x)[0]
        np.testing.assert_allclose(
            np.asarray(direct), np.asarray(jitted), rtol=1e-5, atol=1e-5
        )


def test_artifact_name_convention():
    assert model.artifact_name("vgg_features", 32) == "vgg_features_b32"


def test_repo_manifest_consistent_if_built():
    """If `make artifacts` has run, every listed file must exist and the
    entry count must match stages × batches."""
    root = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    manifest = root / "manifest.json"
    if not manifest.exists():
        pytest.skip("run `make artifacts` first")
    entries = json.loads(manifest.read_text())
    assert len(entries) == len(model.STAGES) * len(model.DEFAULT_BATCHES)
    for e in entries:
        assert (root / e["file"]).exists(), e["file"]
