"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes, dtypes, activations, and block shapes; every
case asserts allclose against ref.py — the core L1 correctness signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul_bias_act, vmem_report
from compile.kernels.stream import stream_scale_add

jax.config.update("jax_enable_x64", False)

ACTIVATIONS = ["none", "relu", "gelu", "tanh", "sigmoid"]


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("activation", ACTIVATIONS)
def test_matmul_all_activations(activation):
    x, w, b = _rand(0, (64, 96), jnp.float32), _rand(1, (96, 80), jnp.float32), _rand(2, (80,), jnp.float32)
    got = matmul_bias_act(x, w, b, activation=activation)
    exp = ref.matmul_bias_act(x, w, b, activation=activation)
    np.testing.assert_allclose(got, exp, rtol=3e-5, atol=3e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 300),
    n=st.integers(1, 300),
    act=st.sampled_from(ACTIVATIONS),
)
def test_matmul_shape_sweep(m, k, n, act):
    x, w, b = _rand(0, (m, k), jnp.float32), _rand(1, (k, n), jnp.float32), _rand(2, (n,), jnp.float32)
    got = matmul_bias_act(x, w, b, activation=act)
    exp = ref.matmul_bias_act(x, w, b, activation=act)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 32, 128, 256]),
    bn=st.sampled_from([8, 32, 128, 256]),
    bk=st.sampled_from([8, 32, 128, 256]),
)
def test_matmul_block_shape_sweep(bm, bn, bk):
    """Result must be invariant to the BlockSpec tiling choice."""
    x, w, b = _rand(0, (100, 120), jnp.float32), _rand(1, (120, 70), jnp.float32), _rand(2, (70,), jnp.float32)
    got = matmul_bias_act(x, w, b, activation="gelu", bm=bm, bn=bn, bk=bk)
    exp = ref.matmul_bias_act(x, w, b, activation="gelu")
    np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-4)


def test_matmul_bf16():
    x = _rand(0, (64, 64), jnp.bfloat16)
    w = _rand(1, (64, 64), jnp.bfloat16)
    b = _rand(2, (64,), jnp.bfloat16)
    got = matmul_bias_act(x, w, b)
    exp = ref.matmul_bias_act(x, w, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(exp, np.float32), rtol=2e-2, atol=2e-2
    )


def test_matmul_rejects_bad_shapes():
    x, w = _rand(0, (4, 5), jnp.float32), _rand(1, (6, 7), jnp.float32)
    b = _rand(2, (7,), jnp.float32)
    with pytest.raises(ValueError, match="contraction"):
        matmul_bias_act(x, w, b)
    w_ok = _rand(1, (5, 7), jnp.float32)
    with pytest.raises(ValueError, match="bias"):
        matmul_bias_act(x, w_ok, _rand(2, (3,), jnp.float32))


def test_vmem_report_structure():
    rep = vmem_report(512, 512, 512)
    assert rep["block"] == (128, 128, 128)
    assert rep["mxu_tile_utilization"] == 1.0
    assert rep["flops"] == 2.0 * 512**3
    # three operand tiles + f32 accumulator + output must fit VMEM (~16 MiB)
    assert rep["vmem_bytes"] < 16 * 2**20


# ---------------------------------------------------------------------------
# stream_scale_add
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 20000),
    passes=st.integers(1, 6),
    scale=st.floats(-2.0, 2.0, allow_nan=False),
)
def test_stream_sweep(n, passes, scale):
    x, y = _rand(0, (n,), jnp.float32), _rand(1, (n,), jnp.float32)
    got = stream_scale_add(x, y, scale=scale, passes=passes)
    exp = ref.stream_scale_add(x, y, scale, passes=passes)
    np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-5)


def test_stream_rejects_mismatch():
    with pytest.raises(ValueError, match="mismatch"):
        stream_scale_add(jnp.zeros(4), jnp.zeros(5))
    with pytest.raises(ValueError, match="1-D"):
        stream_scale_add(jnp.zeros((2, 2)), jnp.zeros((2, 2)))


def test_stream_block_invariance():
    x, y = _rand(0, (5000,), jnp.float32), _rand(1, (5000,), jnp.float32)
    a = stream_scale_add(x, y, scale=0.3, passes=2, block=128)
    c = stream_scale_add(x, y, scale=0.3, passes=2, block=4096)
    np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-7)
