"""L2 correctness: stage models — shapes, determinism, FLOPs accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


@pytest.mark.parametrize("name", sorted(model.STAGES))
def test_stage_shapes(name):
    spec = model.STAGES[name]
    batch = 4
    fwd, (example,) = model.build_stage(spec, batch)
    assert example.shape == (batch, spec.d_in)
    x = jax.random.normal(jax.random.PRNGKey(0), example.shape, example.dtype)
    (y,) = fwd(x)
    assert y.shape == (batch, spec.d_out)
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("name", sorted(model.STAGES))
def test_stage_deterministic(name):
    """build_stage bakes weights from a name-derived key: same name, same fn."""
    spec = model.STAGES[name]
    fwd1, (ex,) = model.build_stage(spec, 2)
    fwd2, _ = model.build_stage(spec, 2)
    x = jax.random.normal(jax.random.PRNGKey(7), ex.shape, ex.dtype)
    np.testing.assert_array_equal(np.asarray(fwd1(x)[0]), np.asarray(fwd2(x)[0]))


def test_mlp_stage_matches_ref_composition():
    """mlp_stage == chained ref.matmul_bias_act."""
    spec = model.StageSpec("t", "mlp", 12, 16, 8, depth=3)
    params = spec.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 12), jnp.float32)
    got = model.mlp_stage(params, x)
    h = x
    for i in range(3):
        w, b = params[2 * i], params[2 * i + 1]
        h = ref.matmul_bias_act(h, w, b, activation="gelu" if i < 2 else "none")
    np.testing.assert_allclose(got, h, rtol=1e-4, atol=1e-4)


def test_lstm_stage_scan_vs_unrolled():
    """The scanned LSTM must equal a hand-unrolled reference cell loop."""
    spec = model.StageSpec("t", "lstm", 8, 6, 4, depth=3)
    wx, wh, b, w_head, b_head = spec.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 8), jnp.float32)
    got = model.lstm_stage((wx, wh, b, w_head, b_head), x, steps=3)

    h = jnp.zeros((5, 6)); c = jnp.zeros((5, 6))
    xp = x @ wx + b
    for _ in range(3):
        gates = xp + h @ wh
        i, f, g, o = jnp.split(gates, 4, axis=1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
    exp = h @ w_head + b_head
    np.testing.assert_allclose(got, exp, rtol=2e-4, atol=2e-4)


def test_stream_stage_matches_ref():
    spec = model.StageSpec("t", "stream", 4096, 0, 4096, depth=2)
    (scale_vec,) = spec.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4096), jnp.float32)
    got = model.stream_stage((scale_vec,), x, passes=2)
    flat = x.reshape(-1)
    other = jnp.tile(scale_vec, 3)[: flat.shape[0]]
    exp = ref.stream_scale_add(flat, other, 0.5, passes=2).reshape(x.shape)
    np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", sorted(model.STAGES))
def test_flops_positive_and_linear_in_batch(name):
    spec = model.STAGES[name]
    f8, f16 = spec.flops_per_query(8), spec.flops_per_query(16)
    assert f8 > 0
    np.testing.assert_allclose(f16, 2 * f8, rtol=1e-6)


def test_param_bytes_matches_init():
    for spec in model.STAGES.values():
        params = spec.init_params(jax.random.PRNGKey(0))
        total = sum(4 * int(np.prod(p.shape)) for p in params)
        assert total == spec.param_bytes()


def test_pipeline_dims_compose():
    """Real-pipeline pairs must chain: stage1.d_out == stage2.d_in."""
    pipelines = [
        ("face_recognition", "fsrcnn_enhance"),
        ("vgg_features", "lstm_caption"),
        ("lstm_semantic", "dcgan_generate"),
        ("bert_summarize", "nmt_translate"),
    ]
    for a, c in pipelines:
        assert model.STAGES[a].d_out == model.STAGES[c].d_in, (a, c)
